//! Artifact manifest: what `aot.py` compiled, with shapes and roles.

use crate::util::json::Json;
use anyhow::{anyhow, Context, Result};
use std::path::{Path, PathBuf};

/// Metadata of one compiled HLO artifact.
#[derive(Clone, Debug)]
pub struct ArtifactMeta {
    pub name: String,
    /// Graph kind: cbe_encode | cbe_project | lsh_encode | bilinear_encode
    /// | opt_encode_b | opt_hg.
    pub kind: String,
    pub d: usize,
    pub batch: usize,
    pub k: Option<usize>,
    /// Input shapes, in argument order.
    pub inputs: Vec<Vec<usize>>,
    /// HLO text file (absolute).
    pub path: PathBuf,
}

/// The parsed manifest.json.
#[derive(Clone, Debug, Default)]
pub struct Manifest {
    pub artifacts: Vec<ArtifactMeta>,
}

impl Manifest {
    /// Load `manifest.json` from an artifacts directory.
    pub fn load(dir: &Path) -> Result<Manifest> {
        let text = std::fs::read_to_string(dir.join("manifest.json"))
            .with_context(|| format!("reading manifest in {}", dir.display()))?;
        let json = Json::parse(&text).map_err(|e| anyhow!("manifest parse: {e}"))?;
        let mut artifacts = Vec::new();
        for a in json
            .get("artifacts")
            .and_then(|v| v.as_arr())
            .ok_or_else(|| anyhow!("manifest missing 'artifacts'"))?
        {
            let get_str = |k: &str| -> Result<String> {
                Ok(a
                    .get(k)
                    .and_then(|v| v.as_str())
                    .ok_or_else(|| anyhow!("artifact missing '{k}'"))?
                    .to_string())
            };
            let get_usize = |k: &str| -> Result<usize> {
                a.get(k)
                    .and_then(|v| v.as_usize())
                    .ok_or_else(|| anyhow!("artifact missing '{k}'"))
            };
            let inputs = a
                .get("inputs")
                .and_then(|v| v.as_arr())
                .ok_or_else(|| anyhow!("artifact missing 'inputs'"))?
                .iter()
                .map(|shape| {
                    shape
                        .as_arr()
                        .unwrap_or(&[])
                        .iter()
                        .filter_map(|d| d.as_usize())
                        .collect()
                })
                .collect();
            artifacts.push(ArtifactMeta {
                name: get_str("name")?,
                kind: get_str("kind")?,
                d: get_usize("d")?,
                batch: get_usize("batch")?,
                k: a.get("k").and_then(|v| v.as_usize()),
                inputs,
                path: dir.join(get_str("path")?),
            });
        }
        Ok(Manifest { artifacts })
    }

    /// Find the artifact for a (kind, d) pair.
    pub fn find(&self, kind: &str, d: usize) -> Option<&ArtifactMeta> {
        self.artifacts.iter().find(|a| a.kind == kind && a.d == d)
    }

    /// All feature dimensions available for a given kind.
    pub fn dims(&self, kind: &str) -> Vec<usize> {
        let mut v: Vec<usize> = self
            .artifacts
            .iter()
            .filter(|a| a.kind == kind)
            .map(|a| a.d)
            .collect();
        v.sort_unstable();
        v.dedup();
        v
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_minimal_manifest() {
        let dir = std::env::temp_dir().join("cbe_manifest_test");
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(
            dir.join("manifest.json"),
            r#"{"artifacts": [{"name": "cbe_encode_d8_b4", "kind": "cbe_encode",
                 "d": 8, "batch": 4, "path": "x.hlo.txt",
                 "inputs": [[4, 8], [8], [8]]}]}"#,
        )
        .unwrap();
        let m = Manifest::load(&dir).unwrap();
        assert_eq!(m.artifacts.len(), 1);
        let a = m.find("cbe_encode", 8).unwrap();
        assert_eq!(a.batch, 4);
        assert_eq!(a.inputs, vec![vec![4, 8], vec![8], vec![8]]);
        assert_eq!(m.dims("cbe_encode"), vec![8]);
        assert!(m.find("cbe_encode", 9).is_none());
    }

    #[test]
    fn real_manifest_if_present() {
        // Exercised against the checked-out artifacts when they exist.
        let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        if dir.join("manifest.json").exists() {
            let m = Manifest::load(&dir).unwrap();
            assert!(!m.artifacts.is_empty());
            for a in &m.artifacts {
                assert!(a.path.exists(), "missing {}", a.path.display());
            }
        }
    }
}
