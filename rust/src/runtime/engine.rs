//! PJRT execution engine: compile HLO-text artifacts once, execute many.

use super::artifact::{ArtifactMeta, Manifest};
use anyhow::{anyhow, Result};
use std::collections::HashMap;
use std::path::Path;

/// Wraps the PJRT CPU client and a cache of compiled executables.
pub struct Engine {
    client: xla::PjRtClient,
    manifest: Manifest,
    compiled: HashMap<String, xla::PjRtLoadedExecutable>,
}

impl Engine {
    /// Create a CPU engine over an artifacts directory.
    pub fn new(artifacts_dir: &Path) -> Result<Engine> {
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("pjrt cpu: {e:?}"))?;
        let manifest = Manifest::load(artifacts_dir)?;
        Ok(Engine {
            client,
            manifest,
            compiled: HashMap::new(),
        })
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    /// Compile (and cache) the executable for an artifact name.
    pub fn load(&mut self, name: &str) -> Result<()> {
        if self.compiled.contains_key(name) {
            return Ok(());
        }
        let meta = self
            .manifest
            .artifacts
            .iter()
            .find(|a| a.name == name)
            .ok_or_else(|| anyhow!("unknown artifact '{name}'"))?
            .clone();
        let path = meta
            .path
            .to_str()
            .ok_or_else(|| anyhow!("non-utf8 path"))?;
        let proto = xla::HloModuleProto::from_text_file(path)
            .map_err(|e| anyhow!("parse {path}: {e:?}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| anyhow!("compile {name}: {e:?}"))?;
        self.compiled.insert(name.to_string(), exe);
        Ok(())
    }

    /// Find an artifact by (kind, d); returns its metadata.
    pub fn find(&self, kind: &str, d: usize) -> Option<ArtifactMeta> {
        self.manifest.find(kind, d).cloned()
    }

    /// Execute an artifact with f32 inputs (shapes per the manifest entry).
    /// Returns the flattened f32 outputs, one Vec per result tuple element.
    pub fn execute(&mut self, name: &str, inputs: &[(&[f32], &[usize])]) -> Result<Vec<Vec<f32>>> {
        self.load(name)?;
        let exe = self.compiled.get(name).unwrap();
        let mut literals = Vec::with_capacity(inputs.len());
        for (data, shape) in inputs {
            let lit = xla::Literal::vec1(data);
            let dims: Vec<i64> = shape.iter().map(|d| *d as i64).collect();
            let lit = lit
                .reshape(&dims)
                .map_err(|e| anyhow!("reshape {shape:?}: {e:?}"))?;
            literals.push(lit);
        }
        let mut result = exe
            .execute::<xla::Literal>(&literals)
            .map_err(|e| anyhow!("execute {name}: {e:?}"))?[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("fetch {name}: {e:?}"))?;
        // aot.py lowers with return_tuple=True.
        let elems = result
            .decompose_tuple()
            .map_err(|e| anyhow!("tuple {name}: {e:?}"))?;
        let mut out = Vec::with_capacity(elems.len());
        for e in elems {
            out.push(e.to_vec::<f32>().map_err(|e2| anyhow!("to_vec: {e2:?}"))?);
        }
        Ok(out)
    }

    /// Number of distinct compiled executables currently cached.
    pub fn loaded_count(&self) -> usize {
        self.compiled.len()
    }
}
