//! PJRT runtime: loads the AOT HLO-text artifacts produced by
//! `python/compile/aot.py` and executes them on the CPU PJRT client.
//!
//! Python never runs at request time — the rust binary is self-contained
//! once `make artifacts` has been run.

pub mod artifact;
pub mod engine;

pub use artifact::{ArtifactMeta, Manifest};
pub use engine::Engine;
