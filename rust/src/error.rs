//! Typed, matchable errors for the serving path.
//!
//! The crate-wide [`crate::Result`] alias is string-backed `anyhow` —
//! right for "something failed, tell the operator" paths, useless for
//! callers that must *dispatch* on the failure. [`CbeError`] is the
//! typed complement: the serving facade returns it where the caller is
//! expected to react programmatically (today: rebuilding a stale index).
//! It implements [`std::error::Error`], so `?` still lifts it into
//! `anyhow::Result` contexts.

use std::fmt;

/// Errors the serving path reports as values a caller can match on.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum CbeError {
    /// A `search()` was issued against an index whose codes were encoded
    /// by a different model than the one serving the query: the index
    /// was stamped at registry version `built`, but the service is at
    /// `current` (trailing = a `Retrain` retired the index's model;
    /// ahead = the index came from another service instance). Mixing
    /// the two silently returns garbage neighbors (the query and the
    /// corpus live in different embeddings), so the service rejects
    /// it — rebuild the index with `EmbeddingService::build_index` and
    /// retry.
    StaleIndex { built: u64, current: u64 },
    /// An on-disk snapshot (or its WAL) failed validation on load:
    /// wrong magic, unsupported format version, a section CRC mismatch,
    /// truncation inside the snapshot body, or a WAL that cannot be
    /// paired with its snapshot generation. The `reason` names the exact
    /// check that failed. Recovery never guesses: a snapshot that fails
    /// any check is rejected whole rather than partially applied.
    CorruptSnapshot { reason: String },
    /// The service's bounded request queue was full: the caller was
    /// rejected at admission instead of growing the queue without limit.
    /// `depth` is the configured queue capacity (`ServiceConfig::
    /// queue_depth` / `CBE_QUEUE_DEPTH`). Back off and retry; rejections
    /// are counted in `StatsSnapshot::overloads`.
    Overloaded { depth: usize },
    /// A requested code length `k` is outside what the configured
    /// projection can produce from `d`-dimensional inputs: a plain
    /// circulant (and a downsampled one) caps at `max = d`, a stacked
    /// model at `max = blocks · d`. Raised at the config seams (spec
    /// parsing, encoder construction, `EmbeddingService::start`) so a
    /// bad `--bits`/`CBE_PROJ` combination is a recoverable error the
    /// operator sees at startup, not an assert abort mid-serve.
    BadCodeLength { k: usize, d: usize, max: usize },
    /// Any other serving failure (encode path, service stopped, …),
    /// carried as its display string.
    Service(String),
}

impl fmt::Display for CbeError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            CbeError::StaleIndex { built, current } => write!(
                f,
                "stale index: built at model version {built}, but the service is at \
                 version {current} — rebuild the index after a retrain"
            ),
            CbeError::CorruptSnapshot { reason } => {
                write!(f, "corrupt snapshot: {reason}")
            }
            CbeError::Overloaded { depth } => write!(
                f,
                "service overloaded: request queue full at depth {depth} — back off and retry"
            ),
            CbeError::BadCodeLength { k, d, max } => write!(
                f,
                "bad code length: k={k} bits requested from a d={d} projection that \
                 produces at most {max} — lower --bits or widen the projection \
                 (e.g. stacked:<B> for k > d)"
            ),
            CbeError::Service(msg) => write!(f, "{msg}"),
        }
    }
}

impl std::error::Error for CbeError {}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn display_names_the_versions() {
        let e = CbeError::StaleIndex { built: 2, current: 5 };
        let s = e.to_string();
        assert!(s.contains("stale index"), "{s}");
        assert!(s.contains('2') && s.contains('5'), "{s}");
    }

    #[test]
    fn corrupt_snapshot_display_carries_the_reason() {
        let e = CbeError::CorruptSnapshot {
            reason: "section 2 crc mismatch".into(),
        };
        let s = e.to_string();
        assert!(s.contains("corrupt snapshot"), "{s}");
        assert!(s.contains("section 2 crc mismatch"), "{s}");
    }

    #[test]
    fn overloaded_display_names_the_depth() {
        let e = CbeError::Overloaded { depth: 256 };
        let s = e.to_string();
        assert!(s.contains("overloaded"), "{s}");
        assert!(s.contains("256"), "{s}");
    }

    #[test]
    fn bad_code_length_display_names_all_three_numbers() {
        let e = CbeError::BadCodeLength { k: 300, d: 128, max: 256 };
        let s = e.to_string();
        assert!(s.contains("bad code length"), "{s}");
        assert!(s.contains("300") && s.contains("128") && s.contains("256"), "{s}");
    }

    #[test]
    fn lifts_into_anyhow() {
        fn inner() -> crate::Result<()> {
            Err(CbeError::StaleIndex { built: 0, current: 1 })?;
            Ok(())
        }
        let msg = inner().unwrap_err().to_string();
        assert!(msg.contains("stale index"), "{msg}");
    }
}
