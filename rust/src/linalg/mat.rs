//! Row-major f32 matrix with blocked matmul.

use crate::util::rng::Pcg64;

/// Row-major dense matrix of f32.
#[derive(Clone, Debug, PartialEq)]
pub struct Mat {
    pub rows: usize,
    pub cols: usize,
    pub data: Vec<f32>,
}

impl Mat {
    pub fn zeros(rows: usize, cols: usize) -> Mat {
        Mat {
            rows,
            cols,
            data: vec![0.0; rows * cols],
        }
    }

    pub fn from_vec(rows: usize, cols: usize, data: Vec<f32>) -> Mat {
        assert_eq!(data.len(), rows * cols);
        Mat { rows, cols, data }
    }

    pub fn eye(n: usize) -> Mat {
        let mut m = Mat::zeros(n, n);
        for i in 0..n {
            m[(i, i)] = 1.0;
        }
        m
    }

    /// Matrix with i.i.d. N(0,1) entries.
    pub fn randn(rows: usize, cols: usize, rng: &mut Pcg64) -> Mat {
        Mat::from_vec(rows, cols, rng.normal_vec(rows * cols))
    }

    #[inline]
    pub fn row(&self, i: usize) -> &[f32] {
        &self.data[i * self.cols..(i + 1) * self.cols]
    }

    #[inline]
    pub fn row_mut(&mut self, i: usize) -> &mut [f32] {
        &mut self.data[i * self.cols..(i + 1) * self.cols]
    }

    pub fn transpose(&self) -> Mat {
        let mut t = Mat::zeros(self.cols, self.rows);
        for i in 0..self.rows {
            for j in 0..self.cols {
                t[(j, i)] = self[(i, j)];
            }
        }
        t
    }

    /// C = A · B, cache-blocked (i-k-j loop order keeps B rows streaming).
    pub fn matmul(&self, b: &Mat) -> Mat {
        assert_eq!(self.cols, b.rows, "matmul shape mismatch");
        let mut c = Mat::zeros(self.rows, b.cols);
        let n = b.cols;
        for i in 0..self.rows {
            let arow = self.row(i);
            let crow = &mut c.data[i * n..(i + 1) * n];
            for (k, &aik) in arow.iter().enumerate() {
                if aik == 0.0 {
                    continue;
                }
                let brow = &b.data[k * n..(k + 1) * n];
                for j in 0..n {
                    crow[j] += aik * brow[j];
                }
            }
        }
        c
    }

    /// C = A · Bᵀ (dot-product form — good when B is given row-major).
    pub fn matmul_t(&self, b: &Mat) -> Mat {
        assert_eq!(self.cols, b.cols, "matmul_t shape mismatch");
        let mut c = Mat::zeros(self.rows, b.rows);
        for i in 0..self.rows {
            let arow = self.row(i);
            for j in 0..b.rows {
                let brow = b.row(j);
                let mut acc = 0f32;
                for k in 0..self.cols {
                    acc += arow[k] * brow[k];
                }
                c[(i, j)] = acc;
            }
        }
        c
    }

    /// Frobenius norm.
    pub fn fro_norm(&self) -> f64 {
        self.data
            .iter()
            .map(|x| *x as f64 * *x as f64)
            .sum::<f64>()
            .sqrt()
    }

    /// Element-wise sign (0 maps to +1 — a bit must be one of ±1).
    pub fn sign(&self) -> Mat {
        Mat::from_vec(
            self.rows,
            self.cols,
            self.data
                .iter()
                .map(|x| if *x >= 0.0 { 1.0 } else { -1.0 })
                .collect(),
        )
    }

    /// Column means.
    pub fn col_means(&self) -> Vec<f32> {
        let mut m = vec![0f64; self.cols];
        for i in 0..self.rows {
            for (j, v) in self.row(i).iter().enumerate() {
                m[j] += *v as f64;
            }
        }
        m.iter().map(|v| (*v / self.rows as f64) as f32).collect()
    }
}

impl std::ops::Index<(usize, usize)> for Mat {
    type Output = f32;
    #[inline]
    fn index(&self, (i, j): (usize, usize)) -> &f32 {
        &self.data[i * self.cols + j]
    }
}
impl std::ops::IndexMut<(usize, usize)> for Mat {
    #[inline]
    fn index_mut(&mut self, (i, j): (usize, usize)) -> &mut f32 {
        &mut self.data[i * self.cols + j]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matmul_identity() {
        let mut rng = Pcg64::new(1);
        let a = Mat::randn(5, 7, &mut rng);
        let i7 = Mat::eye(7);
        let c = a.matmul(&i7);
        for (x, y) in c.data.iter().zip(&a.data) {
            assert!((x - y).abs() < 1e-6);
        }
    }

    #[test]
    fn matmul_known() {
        let a = Mat::from_vec(2, 2, vec![1.0, 2.0, 3.0, 4.0]);
        let b = Mat::from_vec(2, 2, vec![5.0, 6.0, 7.0, 8.0]);
        let c = a.matmul(&b);
        assert_eq!(c.data, vec![19.0, 22.0, 43.0, 50.0]);
    }

    #[test]
    fn matmul_t_consistent() {
        let mut rng = Pcg64::new(2);
        let a = Mat::randn(4, 6, &mut rng);
        let b = Mat::randn(3, 6, &mut rng);
        let c1 = a.matmul_t(&b);
        let c2 = a.matmul(&b.transpose());
        for (x, y) in c1.data.iter().zip(&c2.data) {
            assert!((x - y).abs() < 1e-4);
        }
    }

    #[test]
    fn transpose_involution() {
        let mut rng = Pcg64::new(3);
        let a = Mat::randn(3, 5, &mut rng);
        assert_eq!(a.transpose().transpose(), a);
    }

    #[test]
    fn sign_no_zeros() {
        let a = Mat::from_vec(1, 3, vec![-0.5, 0.0, 2.0]);
        assert_eq!(a.sign().data, vec![-1.0, 1.0, 1.0]);
    }
}
