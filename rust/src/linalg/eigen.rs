//! Symmetric eigensolver: Householder tridiagonalization + implicit QL with
//! Wilkinson shifts. Classic EISPACK `tred2`/`tql2` lineage, f64 throughout.
//!
//! Used by PCA (ITQ / SH / SKLSH baselines in Figure 5).

use super::Mat;

/// Eigen-decomposition of a symmetric matrix.
/// Returns (eigenvalues ascending, eigenvectors as columns of a Mat).
pub fn symmetric_eigen(a: &Mat) -> (Vec<f64>, Mat) {
    let n = a.rows;
    assert_eq!(a.rows, a.cols, "symmetric_eigen needs a square matrix");
    // z: working matrix (becomes eigenvectors), f64 for stability.
    let mut z: Vec<f64> = a.data.iter().map(|x| *x as f64).collect();
    let mut d = vec![0f64; n]; // diagonal
    let mut e = vec![0f64; n]; // off-diagonal

    tred2(&mut z, n, &mut d, &mut e);
    tql2(&mut z, n, &mut d, &mut e);

    let mut vecs = Mat::zeros(n, n);
    for i in 0..n {
        for j in 0..n {
            vecs[(i, j)] = z[i * n + j] as f32;
        }
    }
    (d, vecs)
}

/// Householder reduction of a real symmetric matrix to tridiagonal form.
fn tred2(z: &mut [f64], n: usize, d: &mut [f64], e: &mut [f64]) {
    for i in (1..n).rev() {
        let l = i - 1;
        let mut h = 0.0;
        if l > 0 {
            let scale: f64 = (0..=l).map(|k| z[i * n + k].abs()).sum();
            if scale == 0.0 {
                e[i] = z[i * n + l];
            } else {
                for k in 0..=l {
                    z[i * n + k] /= scale;
                    h += z[i * n + k] * z[i * n + k];
                }
                let mut f = z[i * n + l];
                let g = if f >= 0.0 { -h.sqrt() } else { h.sqrt() };
                e[i] = scale * g;
                h -= f * g;
                z[i * n + l] = f - g;
                f = 0.0;
                for j in 0..=l {
                    z[j * n + i] = z[i * n + j] / h;
                    let mut g = 0.0;
                    for k in 0..=j {
                        g += z[j * n + k] * z[i * n + k];
                    }
                    for k in (j + 1)..=l {
                        g += z[k * n + j] * z[i * n + k];
                    }
                    e[j] = g / h;
                    f += e[j] * z[i * n + j];
                }
                let hh = f / (h + h);
                for j in 0..=l {
                    let f = z[i * n + j];
                    let g = e[j] - hh * f;
                    e[j] = g;
                    for k in 0..=j {
                        z[j * n + k] -= f * e[k] + g * z[i * n + k];
                    }
                }
            }
        } else {
            e[i] = z[i * n + l];
        }
        d[i] = h;
    }
    d[0] = 0.0;
    e[0] = 0.0;
    for i in 0..n {
        let l = i;
        if d[i] != 0.0 {
            for j in 0..l {
                let mut g = 0.0;
                for k in 0..l {
                    g += z[i * n + k] * z[k * n + j];
                }
                for k in 0..l {
                    z[k * n + j] -= g * z[k * n + i];
                }
            }
        }
        d[i] = z[i * n + i];
        z[i * n + i] = 1.0;
        for j in 0..l {
            z[j * n + i] = 0.0;
            z[i * n + j] = 0.0;
        }
    }
}

/// Implicit QL with shifts on the tridiagonal (d, e), accumulating
/// transformations into z. Eigenvalues land in d (ascending after sort).
fn tql2(z: &mut [f64], n: usize, d: &mut [f64], e: &mut [f64]) {
    if n == 0 {
        return;
    }
    for i in 1..n {
        e[i - 1] = e[i];
    }
    e[n - 1] = 0.0;

    for l in 0..n {
        let mut iter = 0;
        loop {
            // Find small subdiagonal element.
            let mut m = l;
            while m + 1 < n {
                let dd = d[m].abs() + d[m + 1].abs();
                if e[m].abs() <= f64::EPSILON * dd {
                    break;
                }
                m += 1;
            }
            if m == l {
                break;
            }
            iter += 1;
            assert!(iter < 50, "tql2 failed to converge");
            // Form shift.
            let mut g = (d[l + 1] - d[l]) / (2.0 * e[l]);
            let mut r = g.hypot(1.0);
            let sign_r = if g >= 0.0 { r } else { -r };
            g = d[m] - d[l] + e[l] / (g + sign_r);
            let (mut s, mut c) = (1.0, 1.0);
            let mut p = 0.0;
            for i in (l..m).rev() {
                let mut f = s * e[i];
                let b = c * e[i];
                r = f.hypot(g);
                e[i + 1] = r;
                if r == 0.0 {
                    d[i + 1] -= p;
                    e[m] = 0.0;
                    break;
                }
                s = f / r;
                c = g / r;
                g = d[i + 1] - p;
                r = (d[i] - g) * s + 2.0 * c * b;
                p = s * r;
                d[i + 1] = g + p;
                g = c * r - b;
                for k in 0..n {
                    f = z[k * n + i + 1];
                    z[k * n + i + 1] = s * z[k * n + i] + c * f;
                    z[k * n + i] = c * z[k * n + i] - s * f;
                }
            }
            if e[l].abs() <= f64::EPSILON * (d[l].abs() + 1.0) && m == l {
                break;
            }
            d[l] -= p;
            e[l] = g;
            e[m] = 0.0;
        }
    }

    // Sort eigenvalues (and vectors) ascending.
    for i in 0..n {
        let mut k = i;
        for j in (i + 1)..n {
            if d[j] < d[k] {
                k = j;
            }
        }
        if k != i {
            d.swap(i, k);
            for row in 0..n {
                z.swap(row * n + i, row * n + k);
            }
        }
    }
}

/// Top-k principal directions of X (rows = samples): returns (k eigenvalues
/// descending, d×k matrix of eigenvectors as columns). Mean-centered.
pub fn top_k_pca(x: &Mat, k: usize) -> (Vec<f64>, Mat) {
    let d = x.cols;
    assert!(k <= d);
    let means = x.col_means();
    // Covariance (d×d, f64 accumulation via f32 matmul on centered data).
    let mut centered = x.clone();
    for i in 0..x.rows {
        for (j, v) in centered.row_mut(i).iter_mut().enumerate() {
            *v -= means[j];
        }
    }
    let cov = {
        let ct = centered.transpose();
        let mut c = ct.matmul_t(&ct); // (d×n)·(d×n)ᵀ = d×d
        let s = 1.0 / (x.rows.max(2) - 1) as f32;
        for v in c.data.iter_mut() {
            *v *= s;
        }
        c
    };
    let (vals, vecs) = symmetric_eigen(&cov);
    // take top-k (eigen returns ascending)
    let dcols = vecs.cols;
    let mut top_vals = Vec::with_capacity(k);
    let mut top = Mat::zeros(d, k);
    for j in 0..k {
        let src = dcols - 1 - j;
        top_vals.push(vals[src]);
        for i in 0..d {
            top[(i, j)] = vecs[(i, src)];
        }
    }
    (top_vals, top)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::qr::orthonormality_error;
    use crate::util::rng::Pcg64;

    fn random_symmetric(n: usize, seed: u64) -> Mat {
        let mut rng = Pcg64::new(seed);
        let g = Mat::randn(n, n, &mut rng);
        let mut s = Mat::zeros(n, n);
        for i in 0..n {
            for j in 0..n {
                s[(i, j)] = 0.5 * (g[(i, j)] + g[(j, i)]);
            }
        }
        s
    }

    #[test]
    fn eigen_reconstructs() {
        for n in [2usize, 5, 16, 33] {
            let a = random_symmetric(n, n as u64);
            let (vals, vecs) = symmetric_eigen(&a);
            // A v_j = λ_j v_j
            for j in 0..n {
                for i in 0..n {
                    let mut av = 0f64;
                    for k in 0..n {
                        av += a[(i, k)] as f64 * vecs[(k, j)] as f64;
                    }
                    let want = vals[j] * vecs[(i, j)] as f64;
                    assert!((av - want).abs() < 1e-3, "n={n} i={i} j={j}");
                }
            }
            assert!(orthonormality_error(&vecs) < 1e-4);
            // ascending
            for j in 1..n {
                assert!(vals[j] >= vals[j - 1] - 1e-12);
            }
        }
    }

    #[test]
    fn eigen_known_2x2() {
        let a = Mat::from_vec(2, 2, vec![2.0, 1.0, 1.0, 2.0]);
        let (vals, _) = symmetric_eigen(&a);
        assert!((vals[0] - 1.0).abs() < 1e-6);
        assert!((vals[1] - 3.0).abs() < 1e-6);
    }

    #[test]
    fn pca_finds_dominant_direction() {
        let mut rng = Pcg64::new(77);
        // Data stretched along (1,1)/√2.
        let n = 500;
        let mut x = Mat::zeros(n, 2);
        for i in 0..n {
            let t = rng.normal() as f32 * 3.0;
            let s = rng.normal() as f32 * 0.1;
            x[(i, 0)] = t + s;
            x[(i, 1)] = t - s;
        }
        let (vals, vecs) = top_k_pca(&x, 1);
        assert!(vals[0] > 10.0);
        let v = (vecs[(0, 0)], vecs[(1, 0)]);
        let align = (v.0 * std::f32::consts::FRAC_1_SQRT_2 + v.1 * std::f32::consts::FRAC_1_SQRT_2)
            .abs();
        assert!(align > 0.99, "align={align}");
    }
}
