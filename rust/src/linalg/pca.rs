//! PCA projection helper shared by the ITQ / SH / SKLSH baselines.

use super::eigen::top_k_pca;
use super::Mat;

/// A fitted PCA transform: subtract mean, project onto top-k components.
#[derive(Clone, Debug)]
pub struct Pca {
    pub mean: Vec<f32>,
    /// d×k projection (columns = principal directions).
    pub components: Mat,
    /// Eigenvalues (variances) of the kept components, descending.
    pub variances: Vec<f64>,
}

impl Pca {
    /// Fit on data rows; keep k components.
    pub fn fit(x: &Mat, k: usize) -> Pca {
        let (variances, components) = top_k_pca(x, k);
        Pca {
            mean: x.col_means(),
            components,
            variances,
        }
    }

    /// Project rows of x into the k-dim PCA space.
    pub fn transform(&self, x: &Mat) -> Mat {
        let k = self.components.cols;
        let mut out = Mat::zeros(x.rows, k);
        for i in 0..x.rows {
            let row = x.row(i);
            for j in 0..k {
                let mut acc = 0f64;
                for (dd, &xv) in row.iter().enumerate() {
                    acc += (xv - self.mean[dd]) as f64 * self.components[(dd, j)] as f64;
                }
                out[(i, j)] = acc as f32;
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg64;

    #[test]
    fn transform_centers_and_orders_variance() {
        let mut rng = Pcg64::new(61);
        let n = 400;
        let mut x = Mat::zeros(n, 3);
        for i in 0..n {
            x[(i, 0)] = rng.normal() as f32 * 5.0 + 10.0;
            x[(i, 1)] = rng.normal() as f32 * 1.0 - 3.0;
            x[(i, 2)] = rng.normal() as f32 * 0.1;
        }
        let pca = Pca::fit(&x, 2);
        let y = pca.transform(&x);
        let means = y.col_means();
        assert!(means.iter().all(|m| m.abs() < 0.5));
        // first component variance > second
        let var = |j: usize| -> f64 {
            (0..n).map(|i| (y[(i, j)] as f64).powi(2)).sum::<f64>() / n as f64
        };
        assert!(var(0) > var(1));
        assert!(pca.variances[0] >= pca.variances[1]);
    }
}
