//! Householder QR decomposition and random orthonormal matrices.
//!
//! Used for: generating random rotations (the Gram–Schmidt construction the
//! paper's Figure-1 simulation uses to place two points at an exact angle in
//! d dimensions), orthogonal initialization of ITQ, and SH's PCA rotations.

use super::Mat;
use crate::util::rng::Pcg64;

/// Compact QR: returns (Q, R) with Q: m×n orthonormal columns (m ≥ n),
/// R: n×n upper triangular, A = Q·R.
pub fn qr(a: &Mat) -> (Mat, Mat) {
    let (m, n) = (a.rows, a.cols);
    assert!(m >= n, "qr requires rows >= cols");
    // Work on column-major copies for cache-friendly column ops.
    let mut w = a.clone(); // will become R in its upper triangle
    let mut vs: Vec<Vec<f32>> = Vec::with_capacity(n); // householder vectors

    for k in 0..n {
        // Build the householder vector from column k, rows k..m.
        let mut v: Vec<f32> = (k..m).map(|i| w[(i, k)]).collect();
        let alpha = {
            let norm = v.iter().map(|x| (*x as f64).powi(2)).sum::<f64>().sqrt() as f32;
            if v[0] >= 0.0 {
                -norm
            } else {
                norm
            }
        };
        if alpha.abs() > 0.0 {
            v[0] -= alpha;
            let vnorm = v.iter().map(|x| (*x as f64).powi(2)).sum::<f64>().sqrt() as f32;
            if vnorm > 1e-20 {
                for x in v.iter_mut() {
                    *x /= vnorm;
                }
                // Apply H = I - 2vvᵀ to the trailing submatrix.
                for j in k..n {
                    let mut dot = 0f64;
                    for (idx, i) in (k..m).enumerate() {
                        dot += v[idx] as f64 * w[(i, j)] as f64;
                    }
                    let dot2 = 2.0 * dot as f32;
                    for (idx, i) in (k..m).enumerate() {
                        w[(i, j)] -= dot2 * v[idx];
                    }
                }
            }
        }
        vs.push(v);
    }

    let mut r = Mat::zeros(n, n);
    for i in 0..n {
        for j in i..n {
            r[(i, j)] = w[(i, j)];
        }
    }

    // Q = H_0 H_1 ... H_{n-1} · [I_n; 0]
    let mut q = Mat::zeros(m, n);
    for i in 0..n {
        q[(i, i)] = 1.0;
    }
    for k in (0..n).rev() {
        let v = &vs[k];
        for j in 0..n {
            let mut dot = 0f64;
            for (idx, i) in (k..m).enumerate() {
                dot += v[idx] as f64 * q[(i, j)] as f64;
            }
            let dot2 = 2.0 * dot as f32;
            for (idx, i) in (k..m).enumerate() {
                q[(i, j)] -= dot2 * v[idx];
            }
        }
    }
    (q, r)
}

/// Random n×n orthonormal matrix (QR of a gaussian matrix, signs fixed so
/// the distribution is Haar).
pub fn random_orthonormal(n: usize, rng: &mut Pcg64) -> Mat {
    let g = Mat::randn(n, n, rng);
    let (mut q, r) = qr(&g);
    // Fix sign ambiguity: make diag(R) positive.
    for j in 0..n {
        if r[(j, j)] < 0.0 {
            for i in 0..n {
                q[(i, j)] = -q[(i, j)];
            }
        }
    }
    q
}

/// Orthonormality residual ‖QᵀQ − I‖_∞ (diagnostic / tests).
pub fn orthonormality_error(q: &Mat) -> f64 {
    let qtq = q.transpose().matmul(q);
    let n = qtq.rows;
    let mut err = 0f64;
    for i in 0..n {
        for j in 0..n {
            let want = if i == j { 1.0 } else { 0.0 };
            err = err.max((qtq[(i, j)] as f64 - want).abs());
        }
    }
    err
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn qr_reconstructs() {
        let mut rng = Pcg64::new(31);
        for (m, n) in [(6, 6), (10, 4), (5, 5)] {
            let a = Mat::randn(m, n, &mut rng);
            let (q, r) = qr(&a);
            let qr_ = q.matmul(&r);
            for (x, y) in qr_.data.iter().zip(&a.data) {
                assert!((x - y).abs() < 1e-4, "m={m} n={n}");
            }
            assert!(orthonormality_error(&q) < 1e-5);
        }
    }

    #[test]
    fn r_upper_triangular() {
        let mut rng = Pcg64::new(37);
        let a = Mat::randn(8, 8, &mut rng);
        let (_, r) = qr(&a);
        for i in 0..8 {
            for j in 0..i {
                assert_eq!(r[(i, j)], 0.0);
            }
        }
    }

    #[test]
    fn random_rotation_orthonormal() {
        let mut rng = Pcg64::new(41);
        let q = random_orthonormal(16, &mut rng);
        assert!(orthonormality_error(&q) < 1e-5);
        // determinant-free sanity: norms of rows are 1
        for i in 0..16 {
            let n: f32 = q.row(i).iter().map(|x| x * x).sum();
            assert!((n - 1.0).abs() < 1e-5);
        }
    }
}
