//! One-sided Jacobi SVD for small square matrices.
//!
//! ITQ's alternating step solves an orthogonal Procrustes problem
//! `R = argmin ‖B − V R‖` whose solution is `R = U Vᵀ` from the SVD of
//! `BᵀV` — a k×k matrix (k = code bits), so a simple Jacobi sweep is plenty.

use super::Mat;

/// SVD of a square matrix A = U · diag(s) · Vᵀ. Returns (U, s, V).
pub fn svd_square(a: &Mat) -> (Mat, Vec<f32>, Mat) {
    let n = a.rows;
    assert_eq!(a.rows, a.cols, "svd_square needs square input");
    // One-sided Jacobi on columns of W = A·V_accum.
    let mut w: Vec<f64> = a.data.iter().map(|x| *x as f64).collect();
    let mut v = vec![0f64; n * n];
    for i in 0..n {
        v[i * n + i] = 1.0;
    }

    let max_sweeps = 60;
    for _sweep in 0..max_sweeps {
        let mut off = 0f64;
        for p in 0..n {
            for q in (p + 1)..n {
                // Compute [app apq; apq aqq] of WᵀW for columns p,q.
                let (mut app, mut aqq, mut apq) = (0f64, 0f64, 0f64);
                for i in 0..n {
                    let wp = w[i * n + p];
                    let wq = w[i * n + q];
                    app += wp * wp;
                    aqq += wq * wq;
                    apq += wp * wq;
                }
                off = off.max(apq.abs() / (app.sqrt() * aqq.sqrt() + 1e-300));
                if apq.abs() < 1e-15 * (app * aqq).sqrt().max(1e-300) {
                    continue;
                }
                // Jacobi rotation zeroing apq.
                let tau = (aqq - app) / (2.0 * apq);
                let t = tau.signum() / (tau.abs() + (1.0 + tau * tau).sqrt());
                let c = 1.0 / (1.0 + t * t).sqrt();
                let s = c * t;
                for i in 0..n {
                    let wp = w[i * n + p];
                    let wq = w[i * n + q];
                    w[i * n + p] = c * wp - s * wq;
                    w[i * n + q] = s * wp + c * wq;
                    let vp = v[i * n + p];
                    let vq = v[i * n + q];
                    v[i * n + p] = c * vp - s * vq;
                    v[i * n + q] = s * vp + c * vq;
                }
            }
        }
        if off < 1e-12 {
            break;
        }
    }

    // Singular values = column norms of W; U = W normalized.
    let mut s = vec![0f32; n];
    let mut u = Mat::zeros(n, n);
    for j in 0..n {
        let norm = (0..n).map(|i| w[i * n + j] * w[i * n + j]).sum::<f64>().sqrt();
        s[j] = norm as f32;
        if norm > 1e-300 {
            for i in 0..n {
                u[(i, j)] = (w[i * n + j] / norm) as f32;
            }
        } else {
            u[(j, j)] = 1.0;
        }
    }
    let mut vm = Mat::zeros(n, n);
    for i in 0..n {
        for j in 0..n {
            vm[(i, j)] = v[i * n + j] as f32;
        }
    }

    // Sort singular values descending (swap columns of U and V).
    let mut order: Vec<usize> = (0..n).collect();
    order.sort_by(|&i, &j| s[j].partial_cmp(&s[i]).unwrap());
    let mut s2 = vec![0f32; n];
    let mut u2 = Mat::zeros(n, n);
    let mut v2 = Mat::zeros(n, n);
    for (newj, &oldj) in order.iter().enumerate() {
        s2[newj] = s[oldj];
        for i in 0..n {
            u2[(i, newj)] = u[(i, oldj)];
            v2[(i, newj)] = vm[(i, oldj)];
        }
    }
    (u2, s2, v2)
}

/// Orthogonal Procrustes: the orthogonal R minimizing ‖A − B·R‖_F,
/// i.e. R = U·Vᵀ where BᵀA = U·diag(s)·Vᵀ ... solved here as
/// `procrustes(M) = U·Vᵀ` for M = BᵀA.
pub fn procrustes_rotation(m: &Mat) -> Mat {
    let (u, _s, v) = svd_square(m);
    u.matmul(&v.transpose())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::qr::{orthonormality_error, random_orthonormal};
    use crate::util::rng::Pcg64;

    #[test]
    fn svd_reconstructs() {
        let mut rng = Pcg64::new(51);
        for n in [2usize, 4, 8, 16] {
            let a = Mat::randn(n, n, &mut rng);
            let (u, s, v) = svd_square(&a);
            // A ?= U diag(s) Vᵀ
            let mut usv = Mat::zeros(n, n);
            for i in 0..n {
                for j in 0..n {
                    let mut acc = 0f64;
                    for k in 0..n {
                        acc += u[(i, k)] as f64 * s[k] as f64 * v[(j, k)] as f64;
                    }
                    usv[(i, j)] = acc as f32;
                }
            }
            for (x, y) in usv.data.iter().zip(&a.data) {
                assert!((x - y).abs() < 1e-3, "n={n}");
            }
            assert!(orthonormality_error(&u) < 1e-4);
            assert!(orthonormality_error(&v) < 1e-4);
            for k in 1..n {
                assert!(s[k] <= s[k - 1] + 1e-6);
            }
        }
    }

    #[test]
    fn procrustes_recovers_rotation() {
        let mut rng = Pcg64::new(53);
        let n = 8;
        let r_true = random_orthonormal(n, &mut rng);
        let b = Mat::randn(50, n, &mut rng);
        let a = b.matmul(&r_true); // A = B R
        let m = b.transpose().matmul(&a); // BᵀA
        let r_hat = procrustes_rotation(&m);
        for (x, y) in r_hat.data.iter().zip(&r_true.data) {
            assert!((x - y).abs() < 1e-3);
        }
    }
}
