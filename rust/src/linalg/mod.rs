//! Dense linear-algebra substrate (no BLAS/LAPACK in the vendor set).
//!
//! Provides what the baselines need: blocked matmul (LSH / bilinear
//! projections), Householder QR (random rotations, orthogonalization),
//! a symmetric eigensolver (PCA for ITQ / SH / SKLSH), and a one-sided
//! Jacobi SVD (ITQ's orthogonal Procrustes step).

pub mod mat;
pub mod qr;
pub mod eigen;
pub mod svd;
pub mod pca;

pub use mat::Mat;
