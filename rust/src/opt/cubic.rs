//! Real-root cubic solver + 1-D quartic minimizer.
//!
//! The per-frequency subproblems of the time–frequency optimization (§4.1,
//! eqs. 21–22) are 4th-order polynomials whose stationary points are roots
//! of a cubic. We solve the depressed cubic t³ + pt + q = 0 in closed form
//! (trigonometric method for three real roots, Cardano otherwise) and pick
//! the root with the lowest quartic value — an exact minimizer, strictly
//! stronger than the paper's "gradient descent for the 2-variable case"
//! (the 2-variable problem reduces to 1-D by rotational symmetry; see
//! [`timefreq`](super::timefreq)).

/// Real roots of t³ + p·t + q = 0 (1 to 3 roots, unsorted).
pub fn depressed_cubic_roots(p: f64, q: f64) -> Vec<f64> {
    if p == 0.0 && q == 0.0 {
        return vec![0.0];
    }
    let disc = -(4.0 * p * p * p + 27.0 * q * q);
    if disc > 0.0 {
        // Three distinct real roots — trigonometric method (p < 0 here).
        let m = 2.0 * (-p / 3.0).sqrt();
        let arg = (3.0 * q / (p * m)).clamp(-1.0, 1.0);
        let theta = arg.acos() / 3.0;
        (0..3)
            .map(|k| m * (theta - 2.0 * std::f64::consts::PI * k as f64 / 3.0).cos())
            .collect()
    } else {
        // One real root — Cardano.
        let half_q = q / 2.0;
        let delta = (half_q * half_q + p * p * p / 27.0).sqrt();
        let u = (-half_q + delta).cbrt();
        let v = (-half_q - delta).cbrt();
        vec![u + v]
    }
}

/// Minimize f(t) = a₄t⁴ + a₂t² + a₁t + a₀ over t ∈ R (a₄ > 0).
/// Returns (argmin, min value).
pub fn minimize_quartic(a4: f64, a2: f64, a1: f64, a0: f64) -> (f64, f64) {
    assert!(a4 > 0.0, "quartic must open upward");
    // f'(t) = 4a₄t³ + 2a₂t + a₁ = 0  →  t³ + (a₂/2a₄)t + a₁/4a₄ = 0
    let p = a2 / (2.0 * a4);
    let q = a1 / (4.0 * a4);
    let f = |t: f64| a4 * t * t * t * t + a2 * t * t + a1 * t + a0;
    let mut best = (0.0, f(0.0));
    for t in depressed_cubic_roots(p, q) {
        let v = f(t);
        if v < best.1 {
            best = (t, v);
        }
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::proptest_lite::forall;

    fn assert_root(p: f64, q: f64, t: f64) {
        let val = t * t * t + p * t + q;
        let scale = 1.0 + t.abs().powi(3) + p.abs() * t.abs() + q.abs();
        assert!(val.abs() / scale < 1e-9, "p={p} q={q} t={t} val={val}");
    }

    #[test]
    fn roots_are_roots() {
        forall("cubic roots satisfy equation", 300, |g| {
            let p = (g.f32_in(-10.0, 10.0)) as f64;
            let q = (g.f32_in(-10.0, 10.0)) as f64;
            let roots = depressed_cubic_roots(p, q);
            assert!(!roots.is_empty());
            for t in roots {
                assert_root(p, q, t);
            }
        });
    }

    #[test]
    fn known_roots() {
        // t³ - 7t + 6 = (t-1)(t-2)(t+3)
        let mut roots = depressed_cubic_roots(-7.0, 6.0);
        roots.sort_by(|a, b| a.partial_cmp(b).unwrap());
        assert!((roots[0] + 3.0).abs() < 1e-9);
        assert!((roots[1] - 1.0).abs() < 1e-9);
        assert!((roots[2] - 2.0).abs() < 1e-9);
    }

    #[test]
    fn quartic_min_beats_grid() {
        forall("closed-form quartic min <= grid search", 200, |g| {
            let a4 = g.f32_in(0.1, 5.0) as f64;
            let a2 = g.f32_in(-10.0, 10.0) as f64;
            let a1 = g.f32_in(-10.0, 10.0) as f64;
            let (t_star, v_star) = minimize_quartic(a4, a2, a1, 0.0);
            let f = |t: f64| a4 * t.powi(4) + a2 * t * t + a1 * t;
            assert!((f(t_star) - v_star).abs() < 1e-9);
            for i in -400..=400 {
                let t = i as f64 * 0.01;
                assert!(f(t) >= v_star - 1e-7, "t={t} f={} v*={v_star}", f(t));
            }
        });
    }
}
