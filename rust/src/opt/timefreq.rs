//! §4: the time–frequency alternating optimization for CBE-opt.
//!
//! Minimizes  ‖B − XRᵀ‖²_F + λ‖RRᵀ − I‖²_F  s.t. R = circ(r)  by
//! alternating:
//!
//! * **time domain** — B = sign(XRᵀ) (eq. 16; columns ≥ k zeroed for the
//!   k < d heuristic of §4.2), and
//! * **frequency domain** — per-DFT-bin closed-form updates of r̃ = F(r).
//!   The objective decomposes (eqs. 20–22) into a 1-variable quartic for
//!   the DC bin (and Nyquist bin when d is even) and a 2-variable quartic
//!   for each conjugate pair. The 2-variable problem
//!   `min m'(a²+b²) + 2λd(a²+b²−1)² + h'a + g'b` is rotationally symmetric
//!   in (a,b) around the linear tilt (h',g'): at the optimum (a,b) points
//!   along −(h',g'), reducing to a 1-D quartic in the radius ρ, which we
//!   minimize in closed form ([`cubic`](super::cubic)). This is exact, so
//!   the overall objective is monotonically non-increasing — checked by
//!   tests and debug assertions.
//!
//! §6 semi-supervised extension: similar/dissimilar pairs add μ·A to the
//! per-bin quadratic coefficient (M → M + μA), nothing else changes.
//!
//! # The half-spectrum cache
//!
//! Every quantity the optimization reads from the data — M (eq. 17), the
//! per-iteration products F(xᵢ) ∘ r̃, the h/g accumulators, the §6 pair
//! penalty, and the full objective — depends on the rows only through
//! their spectra F(xᵢ). Those spectra never change across iterations, and
//! — because every signal here is **real** — they are conjugate
//! symmetric: only the ⌊d/2⌋+1 bins `F(xᵢ)[0..=d/2]` are independent.
//! [`SpectrumCache`] therefore stores exactly that half (built in
//! parallel through [`RealFft`], ~8·n·d bytes instead of the 16·n·d of
//! the full layout), and *every* pass — M, the time-domain sweep,
//! `objective`, `pair_penalty`, the per-bin solve — runs on half-spectra:
//! a mirror bin's contribution to any per-bin reduction equals its
//! partner's (m/h mirror, g negates), so the per-bin solver
//! (`solve_bins_half`) folds the factor of 2 into the solve and never
//! materializes bin d−l. The DC and
//! (even d) Nyquist bins are **enforced** real: `rfft` produces them with
//! exactly zero imaginary part, the solver constructs them real, and
//! `irfft` debug-asserts the contract. Per iteration the trainer runs 2n
//! real FFTs (inverse of the product, forward of the new B rows) — at
//! half size for even d — instead of the 3n+ full-size transforms of the
//! old per-row-re-FFT loop, and `objective`/`pair_penalty` run 0.
//!
//! # The memory budget
//!
//! [`TimeFreqConfig::cache_budget`] caps the resident spectrum bytes.
//! When n·(⌊d/2⌋+1) half-spectra exceed the budget (the 10⁴-row × 25k-dim
//! retrain case), the trainer **tiles**: each pass streams the rows
//! through one reusable tile of block-aligned size, rebuilding tile
//! spectra on the fly (one extra forward FFT per row per pass — the
//! pre-cache cost profile, but with peak memory bounded by one tile).
//! Tile boundaries are aligned to reduction-block boundaries, so the
//! blocked fold order — and therefore every output bit — is **identical**
//! to the untiled run: the budget moves memory, never results.
//!
//! # Threading and determinism
//!
//! The per-row time-domain step and the per-bin frequency accumulation
//! (h, g, M) fan out across core-capped `std::thread::scope` threads,
//! built directly on the PR-3 substrate: one immutable shared [`RealFft`]
//! plan, all mutable state in caller-owned [`RealPackScratch`]-based
//! worker buffers. Reductions are **blocked**: rows are cut into
//! fixed-order blocks, each block accumulates its partial (h, g, err)
//! serially in row order, and partials are folded in ascending block
//! order after the join. With [`TimeFreqConfig::deterministic`] set the
//! block size is a fixed constant, so the reduction tree — and therefore
//! every output bit — is identical at *any* thread count, including the
//! serial cutover (work below the calibrated
//! [`crate::tune::min_parallel_work`] threshold runs the same blocked
//! loop on one thread). With the flag off, blocks are sized per thread
//! (fewer partials; still deterministic for a fixed thread count).

use super::cubic::minimize_quartic;
use crate::fft::realpack::{
    half_len, spectral_corr_accum, spectral_energy_accum, spectral_mul, RealFft, RealPackScratch,
};
use crate::fft::{C64, Dir, FftScratch, Planner};
use crate::linalg::Mat;
use crate::obs::{self, Stage};
use std::time::{Duration, Instant};

/// Fixed reduction-block size (rows) under
/// [`TimeFreqConfig::deterministic`]: small enough that n ≫ block keeps
/// every core busy, large enough that partial buffers stay negligible.
/// Also the tiling granularity floor under
/// [`TimeFreqConfig::cache_budget`].
pub const DETERMINISTIC_BLOCK: usize = 64;

/// Similar/dissimilar pair supervision for the §6 extension.
#[derive(Clone, Debug, Default)]
pub struct PairSet {
    /// Index pairs that should embed near each other.
    pub similar: Vec<(usize, usize)>,
    /// Index pairs that should embed far apart.
    pub dissimilar: Vec<(usize, usize)>,
}

/// Configuration of the optimization.
#[derive(Clone, Debug)]
pub struct TimeFreqConfig {
    /// λ — weight of the near-orthogonality penalty (paper fixes 1.0).
    pub lambda: f64,
    /// Number of alternating iterations (paper: 5–10 suffice).
    pub iters: usize,
    /// Bits to learn (k ≤ d); trailing B columns are zeroed per §4.2.
    pub k: usize,
    /// μ — weight of the semi-supervised term (0 disables it).
    pub mu: f64,
    /// Worker threads for the row fan-out. 0 = auto: all cores when the
    /// total work n·d clears [`crate::tune::min_parallel_work`], else
    /// serial. An explicit count bypasses the work gate (the caller — a
    /// parity test, a bench — knows what it wants).
    pub threads: usize,
    /// Fixed-block reductions: outputs are bit-identical at any thread
    /// count (see module docs). Costs a few extra partial buffers.
    pub deterministic: bool,
    /// Resident spectrum-cache budget in **bytes** (0 = unlimited). When
    /// the half-spectrum cache of the training set would exceed it, the
    /// trainer streams the rows through one block-aligned tile per pass
    /// instead of caching them all — bounded memory, bit-identical
    /// results, one extra forward FFT per row per pass (see module
    /// docs). The floor is one [`DETERMINISTIC_BLOCK`] of rows.
    pub cache_budget: usize,
}

impl TimeFreqConfig {
    pub fn new(k: usize) -> TimeFreqConfig {
        TimeFreqConfig {
            lambda: 1.0,
            iters: 10,
            k,
            mu: 0.0,
            threads: 0,
            deterministic: true,
            cache_budget: 0,
        }
    }
}

/// Convergence + performance record of one training run.
#[derive(Clone, Debug, Default)]
pub struct TrainReport {
    /// Training rows.
    pub n: usize,
    /// Feature dimension.
    pub d: usize,
    /// Iterations run.
    pub iters: usize,
    /// Worker threads the row fan-out actually used (1 = serial
    /// cutover; never exceeds the reduction-block count, so a short
    /// corpus reports the real parallelism, not the requested one).
    pub threads: usize,
    /// Whether fixed-block (thread-count-invariant) reductions were on.
    pub deterministic: bool,
    /// Objective value after each iteration.
    pub objective_trace: Vec<f64>,
    /// Wall milliseconds per iteration.
    pub iter_ms: Vec<f64>,
    /// Total wall milliseconds (including the spectrum-cache build when
    /// the run built one).
    pub total_ms: f64,
    /// Milliseconds building the resident half-spectrum cache (0.0 when
    /// the run streamed tiles instead — their per-pass refills are sweep
    /// work and land in [`TrainReport::sweep_ms`]).
    pub cache_build_ms: f64,
    /// Milliseconds in the time-domain sweep across all iterations: the
    /// M fold, r's forward FFT, and the B = sign(XRᵀ) + h/g fold.
    pub sweep_ms: f64,
    /// Milliseconds in the frequency-domain solve across all iterations:
    /// closed-form per-bin minimizers, the inverse FFT, the objective.
    pub bin_solve_ms: f64,
    /// Bytes resident for row spectra during the run: the whole
    /// half-spectrum cache (16·n·(⌊d/2⌋+1) — about half the PR-4
    /// full-spectrum layout's 16·n·d), or one tile of it when
    /// [`TimeFreqConfig::cache_budget`] forced tiling.
    pub cache_bytes: usize,
    /// Rows per streamed tile when the cache budget forced tiling;
    /// 0 = the whole cache was resident.
    pub tile_rows: usize,
}

/// All row half-spectra F(xᵢ)[0..=d/2], computed once and shared by every
/// pass of the optimization ([`TimeFreqOptimizer::run_cached`],
/// [`TimeFreqOptimizer::objective`], [`TimeFreqOptimizer::pair_penalty`]).
/// Row-major `n × (⌊d/2⌋+1)` complex matrix; 16·n·(⌊d/2⌋+1) bytes — the
/// conjugate-symmetric mirror half is never materialized.
pub struct SpectrumCache {
    /// Rows cached.
    pub n: usize,
    /// Feature dimension (the *full* signal length; rows store
    /// ⌊d/2⌋+1 bins).
    pub d: usize,
    /// Row stride: ⌊d/2⌋ + 1.
    hlen: usize,
    data: Vec<C64>,
}

impl SpectrumCache {
    /// Transform every row of `x` once, fanning rows across up to
    /// `threads` scoped workers (each row is independent, so the build is
    /// bit-exact at any thread count).
    pub fn build(x: &Mat, planner: &Planner, threads: usize) -> SpectrumCache {
        let rfft = RealFft::new(x.cols, planner);
        let mut cache = SpectrumCache::with_capacity(x.cols, x.rows);
        cache.fill(x, 0, x.rows, &rfft, threads);
        cache
    }

    /// An empty cache sized for `rows` rows of dimension d (the trainer's
    /// reusable tile).
    fn with_capacity(d: usize, rows: usize) -> SpectrumCache {
        let hlen = half_len(d);
        SpectrumCache {
            n: 0,
            d,
            hlen,
            data: Vec::with_capacity(rows * hlen),
        }
    }

    /// (Re)fill with the half-spectra of rows [lo, hi) of `x`, fanned
    /// across up to `threads` scoped workers.
    fn fill(&mut self, x: &Mat, lo: usize, hi: usize, rfft: &RealFft, threads: usize) {
        debug_assert_eq!(x.cols, self.d);
        let rows = hi - lo;
        let d = self.d;
        let hlen = self.hlen;
        self.n = rows;
        self.data.resize(rows * hlen, C64::ZERO);
        let src = &x.data[lo * d..hi * d];
        let threads = threads.clamp(1, rows.max(1));
        if threads <= 1 {
            rfft.rfft_batch(src, &mut self.data, &mut RealPackScratch::new());
        } else {
            let rpt = rows.div_ceil(threads);
            std::thread::scope(|scope| {
                for (t, chunk) in self.data.chunks_mut(rpt * hlen).enumerate() {
                    let rows_here = chunk.len() / hlen;
                    let s = &src[t * rpt * d..(t * rpt + rows_here) * d];
                    scope.spawn(move || {
                        rfft.rfft_batch(s, chunk, &mut RealPackScratch::new());
                    });
                }
            });
        }
    }

    /// The cached half-spectrum of row i (len ⌊d/2⌋+1).
    #[inline]
    pub fn row(&self, i: usize) -> &[C64] {
        &self.data[i * self.hlen..(i + 1) * self.hlen]
    }

    /// Half-spectrum row stride: ⌊d/2⌋ + 1.
    pub fn half_len(&self) -> usize {
        self.hlen
    }

    /// Cache footprint in bytes.
    pub fn bytes(&self) -> usize {
        self.data.len() * std::mem::size_of::<C64>()
    }
}

/// State and result of a CBE-opt training run.
pub struct TimeFreqOptimizer {
    pub cfg: TimeFreqConfig,
    pub d: usize,
    /// The shared half-spectrum transform (packed half-size path for
    /// even d, full-size fallback for odd).
    rfft: RealFft,
    /// Objective value after each iteration (for convergence reporting).
    pub objective_trace: Vec<f64>,
    /// Convergence + performance record of the last run.
    pub report: TrainReport,
}

impl TimeFreqOptimizer {
    pub fn new(d: usize, cfg: TimeFreqConfig, planner: Planner) -> TimeFreqOptimizer {
        assert!(cfg.k >= 1 && cfg.k <= d);
        let rfft = RealFft::new(d, &planner);
        TimeFreqOptimizer {
            cfg,
            d,
            rfft,
            objective_trace: Vec::new(),
            report: TrainReport::default(),
        }
    }

    /// Worker threads for a pass over `n` rows: an explicit
    /// `cfg.threads` wins; auto consults the calibrated work threshold.
    fn fanout_threads(&self, n: usize) -> usize {
        if n == 0 {
            return 1;
        }
        if self.cfg.threads != 0 {
            return self.cfg.threads.min(n);
        }
        let cores = std::thread::available_parallelism()
            .map(|p| p.get())
            .unwrap_or(1);
        if cores <= 1 || n * self.d < crate::tune::min_parallel_work() {
            1
        } else {
            cores.min(n)
        }
    }

    /// Reduction-block size (rows) for blocked accumulations.
    fn block_rows(&self, n: usize, threads: usize) -> usize {
        if self.cfg.deterministic {
            DETERMINISTIC_BLOCK
        } else {
            n.div_ceil(threads.max(1)).max(1)
        }
    }

    /// Run the alternating optimization. `x` holds training rows (already
    /// sign-flipped by D). `r0` is the initial circulant vector (CBE-rand
    /// init in the paper). Optional pair supervision. Returns the learned
    /// r.
    ///
    /// When the half-spectrum cache fits [`TimeFreqConfig::cache_budget`]
    /// (or the budget is 0), builds a throwaway [`SpectrumCache`] and
    /// runs [`TimeFreqOptimizer::run_cached`] — callers that already hold
    /// a cache (or need it afterwards for
    /// [`TimeFreqOptimizer::objective`]) should call `run_cached`
    /// directly. Otherwise streams the rows through one block-aligned
    /// tile per pass: bounded memory, bit-identical results.
    pub fn run(&mut self, x: &Mat, r0: &[f32], pairs: Option<&PairSet>) -> Vec<f32> {
        assert_eq!(x.cols, self.d);
        let full_bytes = x.rows * half_len(self.d) * std::mem::size_of::<C64>();
        if self.cfg.cache_budget != 0 && full_bytes > self.cfg.cache_budget {
            return self.run_tiled(x, r0, pairs);
        }
        let t0 = Instant::now();
        let mut cache = SpectrumCache::with_capacity(self.d, x.rows);
        cache.fill(x, 0, x.rows, &self.rfft, self.fanout_threads(x.rows));
        let cache_dur = t0.elapsed();
        obs::record(Stage::CacheBuild, cache_dur);
        let cache_ms = cache_dur.as_secs_f64() * 1e3;
        let r = self.run_cached(&cache, r0, pairs);
        self.report.total_ms += cache_ms;
        self.report.cache_build_ms += cache_ms;
        r
    }

    /// The optimization loop proper, reading row half-spectra from
    /// `cache`.
    pub fn run_cached(
        &mut self,
        cache: &SpectrumCache,
        r0: &[f32],
        pairs: Option<&PairSet>,
    ) -> Vec<f32> {
        let n = cache.n;
        assert_eq!(cache.d, self.d);
        let requested = self.fanout_threads(n);
        let block = self.block_rows(n, requested);
        // What the blocked passes can actually use (≤ one per block) —
        // recorded in the report so it never overstates the fan-out.
        let threads = effective_threads(requested, n, block);
        let pair_m = match pairs {
            Some(ps) if self.cfg.mu != 0.0 => Some(self.pair_penalty(cache, ps)),
            _ => None,
        };
        let plan = PassPlan {
            n,
            block,
            threads,
            cache_bytes: cache.bytes(),
            tile_rows: 0,
        };
        self.run_passes(&mut Tiles::Whole(cache), plan, r0, pair_m)
    }

    /// The budget-bounded run: stream rows through one reusable
    /// block-aligned tile per pass instead of caching every spectrum.
    /// Bit-identical to [`TimeFreqOptimizer::run_cached`] on the same
    /// data — tile boundaries align with reduction-block boundaries, so
    /// the global block partition and fold order are unchanged; only the
    /// resident memory (and one extra forward FFT per row per pass)
    /// differs.
    fn run_tiled(&mut self, x: &Mat, r0: &[f32], pairs: Option<&PairSet>) -> Vec<f32> {
        let d = self.d;
        let n = x.rows;
        let hlen = half_len(d);
        let requested = self.fanout_threads(n);
        // The tiled run always reduces in fixed DETERMINISTIC_BLOCK
        // blocks, whatever `cfg.deterministic` says: per-thread blocks
        // (the non-deterministic sizing) can span the whole corpus,
        // which would raise the tile floor to the full dataset and
        // silently nullify the budget. Under `deterministic` this is
        // the same block the cached run uses — the bit-identity
        // contract; without it there is no cross-mode bit promise to
        // preserve, so honoring the budget wins.
        let block = DETERMINISTIC_BLOCK;

        // Tile size: as many whole reduction blocks as the budget holds
        // (floor: one block). Block alignment is what preserves the fold
        // order of the untiled run.
        let per_row = hlen * std::mem::size_of::<C64>();
        let budget_rows = (self.cfg.cache_budget / per_row.max(1)).max(1);
        let tile_rows = ((budget_rows / block) * block).clamp(block, n.max(block));

        // A blocked pass only ever sees one tile of rows, so the usable
        // fan-out is capped by the blocks *per tile* — report that, not
        // the whole-corpus figure (a tight budget genuinely serializes
        // the sweep, and the report must say so).
        let threads = effective_threads(requested, tile_rows.min(n), block);

        let pair_m = match pairs {
            Some(ps) if self.cfg.mu != 0.0 => Some(self.pair_penalty_rows(x, ps)),
            _ => None,
        };
        let plan = PassPlan {
            n,
            block,
            threads,
            cache_bytes: tile_rows.min(n) * per_row,
            tile_rows,
        };
        let mut tiles = Tiles::Streamed {
            x,
            tile: SpectrumCache::with_capacity(d, tile_rows),
            tile_rows,
            threads,
        };
        self.run_passes(&mut tiles, plan, r0, pair_m)
    }

    /// The one driver behind [`TimeFreqOptimizer::run_cached`] and the
    /// budget-tiled run: M fold, the alternating iterations, the report.
    /// The two entry points differ only in how `tiles` presents the row
    /// spectra (one resident cache vs a streamed tile) and in how the
    /// optional pair penalty was computed — keeping the loop body in one
    /// place is what makes their bit-identity contract a property of the
    /// module, not of two copies.
    fn run_passes(
        &mut self,
        tiles: &mut Tiles,
        plan: PassPlan,
        r0: &[f32],
        pair_m: Option<Vec<f64>>,
    ) -> Vec<f32> {
        let d = self.d;
        assert_eq!(r0.len(), d);
        let hlen = half_len(d);
        let PassPlan {
            n,
            block,
            threads,
            cache_bytes,
            tile_rows,
        } = plan;
        let (k, lambda, iters) = (self.cfg.k, self.cfg.lambda, self.cfg.iters);
        // Cheap clone (tables are small / Arc-shared): lets the closures
        // below hold the transform while `self` stays mutably usable.
        let rfft = self.rfft.clone();

        let t_run = Instant::now();
        // Phase attribution for the report + the obs recorder. The M fold
        // and each iteration's time-domain pass are "sweep"; the per-bin
        // closed-form solve + inverse FFT + objective are "bin-solve".
        let mut sweep_dur = Duration::ZERO;
        let mut solve_dur = Duration::ZERO;

        // ---- Precompute M (eq. 17) on the half-spectrum:
        // m_l = Σ_i |F(x_i)_l|² for l ≤ ⌊d/2⌋, plus μ·A (§6).
        let t_sweep = Instant::now();
        let mut m = vec![0f64; hlen];
        tiles.for_each(&rfft, |cache| {
            for p in m_partials(cache, block, threads) {
                for (t, v) in m.iter_mut().zip(&p) {
                    *t += *v;
                }
            }
        });
        if let Some(a) = pair_m {
            for (t, v) in m.iter_mut().zip(&a) {
                *t += self.cfg.mu * *v;
            }
        }
        sweep_dur += t_sweep.elapsed();

        let mut r = r0.to_vec();
        self.objective_trace.clear();
        let mut iter_ms = Vec::with_capacity(iters);
        let mut scratch = RealPackScratch::new();
        let mut r_spec = vec![C64::ZERO; hlen];

        for _iter in 0..iters {
            let t_iter = Instant::now();
            let t_sweep = t_iter;
            rfft.rfft(&r, &mut r_spec, &mut scratch);

            // ---- Time-domain pass: B = sign(XRᵀ) with cols ≥ k zeroed,
            // h/g (eq. 17) accumulated per half-spectrum bin in the same
            // sweep — fanned across the row blocks, folded in ascending
            // block order across tiles.
            let mut h = vec![0f64; hlen];
            let mut g = vec![0f64; hlen];
            let mut err = 0f64;
            tiles.for_each(&rfft, |cache| {
                fold_time_domain(
                    time_domain_partials(cache, &r_spec, k, &rfft, block, threads),
                    &mut h,
                    &mut g,
                    &mut err,
                );
            });

            // ---- Frequency-domain pass: closed-form per-bin minimizers.
            let t_solve = Instant::now();
            sweep_dur += t_solve.duration_since(t_sweep);
            let spec = solve_bins_half(&m, &h, &g, &r_spec, lambda, d);
            rfft.irfft(&spec, &mut r, &mut scratch);

            // ---- Objective for the trace (eq. 15, with the new B fixed
            // implicitly — we log binarization error of the *previous* r
            // plus the orthogonality penalty of the *new* r̃; monotonicity
            // of the true objective is asserted in tests on small cases).
            self.objective_trace
                .push(err + lambda * ortho_half(&spec, d));
            solve_dur += t_solve.elapsed();
            iter_ms.push(t_iter.elapsed().as_secs_f64() * 1e3);
        }
        obs::record(Stage::Sweep, sweep_dur);
        obs::record(Stage::BinSolve, solve_dur);

        self.report = TrainReport {
            n,
            d,
            iters,
            threads,
            deterministic: self.cfg.deterministic,
            objective_trace: self.objective_trace.clone(),
            iter_ms,
            total_ms: t_run.elapsed().as_secs_f64() * 1e3,
            // `run()` folds the resident cache build in after this
            // literal; tiled runs have no separate build phase.
            cache_build_ms: 0.0,
            sweep_ms: sweep_dur.as_secs_f64() * 1e3,
            bin_solve_ms: solve_dur.as_secs_f64() * 1e3,
            cache_bytes,
            tile_rows,
        };
        r
    }

    /// §6: per-bin penalty a_l = Σ_{M} |F(x_i)_l − F(x_j)_l|² −
    /// Σ_{D} |F(x_i)_l − F(x_j)_l|², on the half-spectrum bins. Reads the
    /// shared spectrum cache — no FFTs at all.
    pub fn pair_penalty(&self, cache: &SpectrumCache, ps: &PairSet) -> Vec<f64> {
        let hlen = cache.hlen;
        let mut a = vec![0f64; hlen];
        let mut add = |i: usize, j: usize, sign: f64| {
            let xi = cache.row(i);
            let xj = cache.row(j);
            for l in 0..hlen {
                a[l] += sign * (xi[l] - xj[l]).norm_sqr();
            }
        };
        for &(i, j) in &ps.similar {
            add(i, j, 1.0);
        }
        for &(i, j) in &ps.dissimilar {
            add(i, j, -1.0);
        }
        a
    }

    /// [`TimeFreqOptimizer::pair_penalty`] without a resident cache (the
    /// tiled path): re-transforms each pair row on the fly. Same
    /// arithmetic, same accumulation order, bit-identical result.
    fn pair_penalty_rows(&self, x: &Mat, ps: &PairSet) -> Vec<f64> {
        let hlen = half_len(self.d);
        let mut scratch = RealPackScratch::new();
        let mut si = vec![C64::ZERO; hlen];
        let mut sj = vec![C64::ZERO; hlen];
        let mut a = vec![0f64; hlen];
        for (pairs, sign) in [(&ps.similar, 1.0), (&ps.dissimilar, -1.0)] {
            for &(i, j) in pairs {
                self.rfft.rfft(x.row(i), &mut si, &mut scratch);
                self.rfft.rfft(x.row(j), &mut sj, &mut scratch);
                for l in 0..hlen {
                    a[l] += sign * (si[l] - sj[l]).norm_sqr();
                }
            }
        }
        a
    }

    /// Evaluate the full objective (eq. 15) for given r against the
    /// cached row half-spectra — used by tests to verify monotone descent
    /// and by the equality test against [`reference::objective`]. Zero
    /// FFTs over the data (only r's forward transform and n inverse
    /// transforms of the spectral product).
    pub fn objective(&self, cache: &SpectrumCache, r: &[f32]) -> f64 {
        let d = self.d;
        assert_eq!(cache.d, d);
        let hlen = cache.hlen;
        let mut scratch = RealPackScratch::new();
        let mut r_spec = vec![C64::ZERO; hlen];
        self.rfft.rfft(r, &mut r_spec, &mut scratch);
        let mut yspec = vec![C64::ZERO; hlen];
        let mut y = vec![0f64; d];
        let mut bin_err = 0f64;
        for i in 0..cache.n {
            spectral_mul(cache.row(i), &r_spec, &mut yspec);
            self.rfft.irfft_f64(&yspec, &mut y, &mut scratch);
            for (j, yv) in y.iter().enumerate() {
                let b = if j < self.cfg.k {
                    if *yv >= 0.0 {
                        1.0
                    } else {
                        -1.0
                    }
                } else {
                    0.0
                };
                let e = b - *yv;
                bin_err += e * e;
            }
        }
        bin_err + self.cfg.lambda * ortho_half(&r_spec, d)
    }
}

// ------------------------------------------------------------------ passes

/// How a run presents its row spectra to the blocked passes: one
/// resident [`SpectrumCache`], or a reusable block-aligned tile refilled
/// from the training matrix on every pass (the
/// [`TimeFreqConfig::cache_budget`] mode).
enum Tiles<'a> {
    Whole(&'a SpectrumCache),
    Streamed {
        x: &'a Mat,
        tile: SpectrumCache,
        tile_rows: usize,
        threads: usize,
    },
}

impl Tiles<'_> {
    /// Visit the row spectra tile by tile in ascending row order (the
    /// whole cache is one tile). Tile boundaries are block-aligned, so
    /// the per-block partials the visitor folds arrive in the same order
    /// in both modes — the bit-identity contract between them.
    fn for_each(&mut self, rfft: &RealFft, mut f: impl FnMut(&SpectrumCache)) {
        match self {
            Tiles::Whole(cache) => f(*cache),
            Tiles::Streamed {
                x,
                tile,
                tile_rows,
                threads,
            } => {
                let n = x.rows;
                let mut lo = 0;
                while lo < n {
                    let hi = (lo + *tile_rows).min(n);
                    tile.fill(x, lo, hi, rfft, *threads);
                    f(tile);
                    lo = hi;
                }
            }
        }
    }
}

/// Shape of one training run's blocked passes, shared by the cached and
/// tiled drivers (plus what the report should record about residency).
struct PassPlan {
    n: usize,
    block: usize,
    threads: usize,
    /// Resident spectrum bytes (whole cache, or one tile).
    cache_bytes: usize,
    /// Tile granularity; 0 = whole cache resident.
    tile_rows: usize,
}

/// Per-block partial of the time-domain sweep (half-spectrum h/g).
struct PassAccum {
    h: Vec<f64>,
    g: Vec<f64>,
    err: f64,
}

impl PassAccum {
    fn new(hlen: usize) -> PassAccum {
        PassAccum {
            h: vec![0f64; hlen],
            g: vec![0f64; hlen],
            err: 0.0,
        }
    }
}

/// Per-worker mutable state of the time-domain sweep.
struct PassState {
    /// Half-spectrum of the product F(xᵢ) ∘ r̃, len ⌊d/2⌋+1.
    yspec: Vec<C64>,
    /// Time-domain projection Rxᵢ at full f64 precision, len d (the
    /// binarization error feeds the objective trace, so rounding through
    /// f32 here would perturb it).
    y: Vec<f64>,
    /// Half-spectrum of FFT(bᵢ), len ⌊d/2⌋+1.
    bspec: Vec<C64>,
    /// Binarized row bᵢ, len d.
    bi: Vec<f32>,
    rp: RealPackScratch,
}

impl PassState {
    fn new(d: usize, hlen: usize) -> PassState {
        PassState {
            yspec: vec![C64::ZERO; hlen],
            y: vec![0f64; d],
            bspec: vec![C64::ZERO; hlen],
            bi: vec![0f32; d],
            rp: RealPackScratch::new(),
        }
    }
}

/// Accumulate rows [lo, hi) of the time-domain sweep into `acc`,
/// strictly in ascending row order (the in-block reduction order every
/// mode shares).
#[allow(clippy::too_many_arguments)]
fn pass_rows(
    cache: &SpectrumCache,
    r_spec: &[C64],
    k: usize,
    rfft: &RealFft,
    lo: usize,
    hi: usize,
    acc: &mut PassAccum,
    st: &mut PassState,
) {
    for i in lo..hi {
        let xf = cache.row(i);
        // y = R x_i via spectral product on the cached half-spectrum.
        spectral_mul(xf, r_spec, &mut st.yspec);
        rfft.irfft_f64(&st.yspec, &mut st.y, &mut st.rp);
        for (j, yv) in st.y.iter().enumerate() {
            let b = if j < k {
                if *yv >= 0.0 {
                    1.0
                } else {
                    -1.0
                }
            } else {
                0.0
            };
            st.bi[j] = b as f32;
            let e = b - *yv;
            acc.err += e * e;
        }
        rfft.rfft(&st.bi, &mut st.bspec, &mut st.rp);
        spectral_corr_accum(xf, &st.bspec, &mut acc.h, &mut acc.g);
    }
}

/// Blocks (and therefore reduction-tree shape) for `n` rows cut into
/// `block`-row blocks.
fn block_count(n: usize, block: usize) -> usize {
    n.div_ceil(block.max(1)).max(1)
}

/// Worker threads a blocked pass can actually use (never more than one
/// per block) — also what [`TrainReport::threads`] records.
fn effective_threads(threads: usize, n: usize, block: usize) -> usize {
    threads.clamp(1, block_count(n, block))
}

/// The one blocked fan-out behind every trainer reduction: rows [0, n)
/// are cut into `block`-row blocks, each block accumulates into its own
/// slot (`body` is called with the block's [lo, hi) row range), and
/// contiguous runs of blocks go to scoped worker threads, each with its
/// own `new_state()` worker state. Returns the per-block partials in
/// block order — the caller folds them 0..nblocks, so the reduction
/// tree depends only on `block`, never on the thread count. Keeping the
/// partition/spawn/fold discipline in exactly one place is what makes
/// the determinism contract a property of the module, not of each pass.
fn blocked_partials<A: Send, S>(
    n: usize,
    block: usize,
    threads: usize,
    new_accum: impl Fn() -> A + Sync,
    new_state: impl Fn() -> S + Sync,
    body: impl Fn(usize, usize, &mut A, &mut S) + Sync,
) -> Vec<A> {
    let block = block.max(1);
    let nblocks = block_count(n, block);
    let mut partials: Vec<A> = (0..nblocks).map(|_| new_accum()).collect();
    let threads = effective_threads(threads, n, block);
    let run_blocks = |first_block: usize, slots: &mut [A]| {
        let mut st = new_state();
        for (s, acc) in slots.iter_mut().enumerate() {
            let b = first_block + s;
            body(b * block, ((b + 1) * block).min(n), acc, &mut st);
        }
    };
    if threads <= 1 {
        run_blocks(0, &mut partials[..]);
    } else {
        let bpt = nblocks.div_ceil(threads);
        std::thread::scope(|scope| {
            for (t, chunk) in partials.chunks_mut(bpt).enumerate() {
                let run_blocks = &run_blocks;
                scope.spawn(move || run_blocks(t * bpt, chunk));
            }
        });
    }
    partials
}

/// The parallel time-domain sweep, as a blocked reduction returning the
/// per-block [`PassAccum`] partials in block order (the caller folds —
/// [`fold_time_domain`] — so tiled runs can keep one running total
/// across tiles without changing the fold sequence).
fn time_domain_partials(
    cache: &SpectrumCache,
    r_spec: &[C64],
    k: usize,
    rfft: &RealFft,
    block: usize,
    threads: usize,
) -> Vec<PassAccum> {
    let d = cache.d;
    let hlen = cache.hlen;
    blocked_partials(
        cache.n,
        block,
        threads,
        || PassAccum::new(hlen),
        || PassState::new(d, hlen),
        |lo, hi, acc: &mut PassAccum, st: &mut PassState| {
            pass_rows(cache, r_spec, k, rfft, lo, hi, acc, st);
        },
    )
}

/// Fold time-domain partials into the running (h, g, err) totals, in
/// the order given (ascending block order).
fn fold_time_domain(partials: Vec<PassAccum>, h: &mut [f64], g: &mut [f64], err: &mut f64) {
    for p in &partials {
        for (t, v) in h.iter_mut().zip(&p.h) {
            *t += *v;
        }
        for (t, v) in g.iter_mut().zip(&p.g) {
            *t += *v;
        }
        *err += p.err;
    }
}

/// Blocked-parallel M partials: m_l = Σ_i |F(x_i)_l|² on half-spectrum
/// bins, same reduction discipline as [`time_domain_partials`].
fn m_partials(cache: &SpectrumCache, block: usize, threads: usize) -> Vec<Vec<f64>> {
    let hlen = cache.hlen;
    blocked_partials(
        cache.n,
        block,
        threads,
        || vec![0f64; hlen],
        || (),
        |lo, hi, acc: &mut Vec<f64>, _: &mut ()| {
            for i in lo..hi {
                spectral_energy_accum(cache.row(i), acc);
            }
        },
    )
}

/// Σ_l (|r̃_l|² − 1)² over all d bins, evaluated on the half layout:
/// DC (and Nyquist, even d) count once, every conjugate pair twice.
fn ortho_half(spec: &[C64], d: usize) -> f64 {
    let mut o = (spec[0].norm_sqr() - 1.0).powi(2);
    let pair_end = if d % 2 == 0 && d >= 2 {
        o += (spec[d / 2].norm_sqr() - 1.0).powi(2);
        d / 2
    } else {
        spec.len()
    };
    for c in &spec[1..pair_end] {
        o += 2.0 * (c.norm_sqr() - 1.0).powi(2);
    }
    o
}

/// The frequency-domain pass on the half layout: closed-form per-bin
/// minimizers given the half-accumulated (M, h, g) and the previous
/// half-spectrum (for the tilt-free tie-break). Conjugate symmetry makes
/// each paired bin's primed coefficients exactly twice its own
/// (m' = mᵢ + m_{d−i} = 2mᵢ, h' = 2hᵢ, g' = gᵢ − g_{d−i} = 2gᵢ), so the
/// solve never touches a mirror bin; the DC and Nyquist bins are
/// constructed exactly real, which is what lets `irfft` assume (and
/// debug-assert) the realness contract. Bit-for-bit equal to the full
/// [`solve_bins`] on mirrored inputs — pinned by a test. (λ = 0 would
/// degenerate the quartics; clamp keeps them convex.)
fn solve_bins_half(
    m: &[f64],
    h: &[f64],
    g: &[f64],
    r_spec: &[C64],
    lambda: f64,
    d: usize,
) -> Vec<C64> {
    let lam_d = (lambda * d as f64).max(1e-9);
    let hlen = m.len();
    let mut spec = vec![C64::ZERO; hlen];

    // DC bin (eq. 21): min m₀t² + h₀t + λd(t²−1)², t real.
    // = λd·t⁴ + (m₀ − 2λd)t² + h₀t + λd
    let (t0, _) = minimize_quartic(lam_d, m[0] - 2.0 * lam_d, h[0], lam_d);
    spec[0] = C64::new(t0, 0.0);

    // Nyquist bin for even d — same 1-variable form.
    let pair_end = if d % 2 == 0 && d >= 2 {
        let l = d / 2;
        let (t, _) = minimize_quartic(lam_d, m[l] - 2.0 * lam_d, h[l], lam_d);
        spec[l] = C64::new(t, 0.0);
        l
    } else {
        hlen
    };

    // Conjugate pairs (eq. 22): variables a = Re(r̃_i), b = Im(r̃_i).
    //   f(a,b) = m'(a²+b²) + 2λd(a²+b²−1)² + h'a + g'b
    // with m' = 2mᵢ, h' = 2hᵢ, g' = 2gᵢ (symmetry; see above).
    // Radial reduction: (a,b) = −ρ·(h',g')/‖(h',g')‖ and minimize
    //   f(ρ) = 2λd·ρ⁴ + (m' − 4λd)ρ² − ‖(h',g')‖ρ  over ρ ∈ R.
    for i in 1..pair_end {
        let mp = 2.0 * m[i];
        let hp = 2.0 * h[i];
        let gp = 2.0 * g[i];
        let cnorm = (hp * hp + gp * gp).sqrt();
        let a4 = 2.0 * lam_d;
        let a2 = mp - 4.0 * lam_d;
        let (re, im) = if cnorm > 1e-300 {
            let (rho, _) = minimize_quartic(a4, a2, -cnorm, 2.0 * lam_d);
            // rho may come out negative if the cubic picked the
            // mirrored root; fold the sign into the direction.
            (-rho * hp / cnorm, -rho * gp / cnorm)
        } else {
            // No linear tilt: pick the radius minimizing the radial
            // part, direction along previous iterate for stability.
            let rho2 = ((4.0 * lam_d - mp) / (4.0 * lam_d)).max(0.0);
            let rho = rho2.sqrt();
            let prev = r_spec[i];
            let pn = prev.abs();
            if pn > 1e-300 {
                (rho * prev.re / pn, rho * prev.im / pn)
            } else {
                (rho, 0.0)
            }
        };
        spec[i] = C64::new(re, im);
    }
    spec
}

/// The full-spectrum frequency-domain pass, kept for the [`reference`]
/// oracles (the trainer itself runs [`solve_bins_half`]; the two agree
/// bit-for-bit on mirrored inputs — pinned by a test).
fn solve_bins(
    m: &[f64],
    h: &[f64],
    g: &[f64],
    r_spec: &[C64],
    lambda: f64,
    d: usize,
) -> Vec<C64> {
    let lam_d = (lambda * d as f64).max(1e-9);
    let mut spec = vec![C64::ZERO; d];

    let (t0, _) = minimize_quartic(lam_d, m[0] - 2.0 * lam_d, h[0], lam_d);
    spec[0] = C64::new(t0, 0.0);

    if d % 2 == 0 {
        let l = d / 2;
        let (t, _) = minimize_quartic(lam_d, m[l] - 2.0 * lam_d, h[l], lam_d);
        spec[l] = C64::new(t, 0.0);
    }

    for i in 1..=(d - 1) / 2 {
        let mp = m[i] + m[d - i];
        let hp = h[i] + h[d - i];
        let gp = g[i] - g[d - i];
        let cnorm = (hp * hp + gp * gp).sqrt();
        let a4 = 2.0 * lam_d;
        let a2 = mp - 4.0 * lam_d;
        let (re, im) = if cnorm > 1e-300 {
            let (rho, _) = minimize_quartic(a4, a2, -cnorm, 2.0 * lam_d);
            (-rho * hp / cnorm, -rho * gp / cnorm)
        } else {
            let rho2 = ((4.0 * lam_d - mp) / (4.0 * lam_d)).max(0.0);
            let rho = rho2.sqrt();
            let prev = r_spec[i];
            let pn = prev.abs();
            if pn > 1e-300 {
                (rho * prev.re / pn, rho * prev.im / pn)
            } else {
                (rho, 0.0)
            }
        };
        spec[i] = C64::new(re, im);
        spec[d - i] = C64::new(re, -im);
    }
    spec
}

// --------------------------------------------------------------- reference

/// Pre-half-spectrum trainers, kept verbatim as measurement baselines for
/// `cargo bench --bench train_throughput` and as oracles for the
/// refactors' tests:
///
/// * [`reference::run`] — the original serial loop that recomputes
///   `F(xᵢ)` for every row in every iteration;
/// * [`reference::run_full_cache`] — the PR-4 layout: spectra cached
///   once, but as **full** d-point complex rows (16·n·d bytes, full-size
///   per-iteration transforms).
///
/// Never use them to train — they exist to be compared against.
pub mod reference {
    use super::*;
    use crate::fft::real;

    /// The old serial run loop (per-row re-FFT everywhere). Returns the
    /// learned r and the objective trace.
    pub fn run(
        planner: &Planner,
        d: usize,
        cfg: &TimeFreqConfig,
        x: &Mat,
        r0: &[f32],
        pairs: Option<&PairSet>,
    ) -> (Vec<f32>, Vec<f64>) {
        let n = x.rows;
        assert_eq!(x.cols, d);
        assert_eq!(r0.len(), d);

        let mut m = vec![0f64; d];
        for i in 0..n {
            let xf = real::rfft_full(planner, x.row(i));
            for (l, c) in xf.iter().enumerate() {
                m[l] += c.norm_sqr();
            }
        }
        if let Some(ps) = pairs {
            if cfg.mu != 0.0 {
                let a = pair_penalty(planner, d, x, ps);
                for l in 0..d {
                    m[l] += cfg.mu * a[l];
                }
            }
        }

        let mut r = r0.to_vec();
        let mut trace = Vec::new();

        for _iter in 0..cfg.iters {
            let r_spec = real::rfft_full(planner, &r);
            let mut h = vec![0f64; d];
            let mut g = vec![0f64; d];
            let mut binarization_err = 0f64;

            let mut bi = vec![0f32; d];
            for i in 0..n {
                let xf = real::rfft_full(planner, x.row(i));
                let mut yspec: Vec<C64> =
                    xf.iter().zip(&r_spec).map(|(a, b)| *a * *b).collect();
                planner.ifft(&mut yspec);
                for j in 0..d {
                    let y = yspec[j].re;
                    let b = if j < cfg.k {
                        if y >= 0.0 {
                            1.0
                        } else {
                            -1.0
                        }
                    } else {
                        0.0
                    };
                    bi[j] = b as f32;
                    let e = b - y;
                    binarization_err += e * e;
                }
                let bf = real::rfft_full(planner, &bi);
                for l in 0..d {
                    h[l] -= 2.0 * (xf[l].re * bf[l].re + xf[l].im * bf[l].im);
                    g[l] += 2.0 * (xf[l].im * bf[l].re - xf[l].re * bf[l].im);
                }
            }

            let spec = solve_bins(&m, &h, &g, &r_spec, cfg.lambda, d);
            r = real::irfft_full(planner, &spec);

            let ortho: f64 = spec.iter().map(|c| (c.norm_sqr() - 1.0).powi(2)).sum();
            trace.push(binarization_err + cfg.lambda * ortho);
        }
        (r, trace)
    }

    /// The PR-4 full-spectrum cached serial trainer: every row spectrum
    /// cached once as a full d-point complex row (16·n·d bytes — twice
    /// the half layout), one full-size inverse + forward transform per
    /// row per iteration. The bench's `full` arm, so the half-spectrum
    /// engine is measured against the exact layout it replaced. Returns
    /// (learned r, objective trace, per-iteration seconds, cache bytes).
    /// Bit-identical to [`run`] — pinned by a test.
    pub fn run_full_cache(
        planner: &Planner,
        d: usize,
        cfg: &TimeFreqConfig,
        x: &Mat,
        r0: &[f32],
    ) -> (Vec<f32>, Vec<f64>, Vec<f64>, usize) {
        let n = x.rows;
        assert_eq!(x.cols, d);
        assert_eq!(r0.len(), d);
        let plan = planner.plan(d);
        let mut scratch = FftScratch::new();

        let mut cache = vec![C64::ZERO; n * d];
        for i in 0..n {
            let row = &mut cache[i * d..(i + 1) * d];
            for (c, v) in row.iter_mut().zip(x.row(i)) {
                *c = C64::new(*v as f64, 0.0);
            }
            plan.transform_with(row, Dir::Forward, &mut scratch);
        }
        let cache_bytes = cache.len() * std::mem::size_of::<C64>();

        let mut m = vec![0f64; d];
        for i in 0..n {
            for (l, c) in cache[i * d..(i + 1) * d].iter().enumerate() {
                m[l] += c.norm_sqr();
            }
        }

        let mut r = r0.to_vec();
        let mut trace = Vec::new();
        let mut iter_s = Vec::new();
        let mut yspec = vec![C64::ZERO; d];
        let mut cplx = vec![C64::ZERO; d];
        let mut bi = vec![0f32; d];
        for _iter in 0..cfg.iters {
            let t0 = Instant::now();
            let mut r_spec: Vec<C64> = r.iter().map(|v| C64::new(*v as f64, 0.0)).collect();
            plan.transform_with(&mut r_spec, Dir::Forward, &mut scratch);
            let mut h = vec![0f64; d];
            let mut g = vec![0f64; d];
            let mut err = 0f64;
            for i in 0..n {
                let xf = &cache[i * d..(i + 1) * d];
                yspec.copy_from_slice(xf);
                for (y, rs) in yspec.iter_mut().zip(&r_spec) {
                    *y = *y * *rs;
                }
                plan.transform_with(&mut yspec, Dir::Inverse, &mut scratch);
                for j in 0..d {
                    let y = yspec[j].re;
                    let b = if j < cfg.k {
                        if y >= 0.0 {
                            1.0
                        } else {
                            -1.0
                        }
                    } else {
                        0.0
                    };
                    bi[j] = b as f32;
                    let e = b - y;
                    err += e * e;
                }
                for (c, v) in cplx.iter_mut().zip(bi.iter()) {
                    *c = C64::new(*v as f64, 0.0);
                }
                plan.transform_with(&mut cplx, Dir::Forward, &mut scratch);
                for l in 0..d {
                    h[l] -= 2.0 * (xf[l].re * cplx[l].re + xf[l].im * cplx[l].im);
                    g[l] += 2.0 * (xf[l].im * cplx[l].re - xf[l].re * cplx[l].im);
                }
            }
            let spec = solve_bins(&m, &h, &g, &r_spec, cfg.lambda, d);
            let mut buf = spec.clone();
            plan.transform_with(&mut buf, Dir::Inverse, &mut scratch);
            r = buf.iter().map(|c| c.re as f32).collect();
            let ortho: f64 = spec.iter().map(|c| (c.norm_sqr() - 1.0).powi(2)).sum();
            trace.push(err + cfg.lambda * ortho);
            iter_s.push(t0.elapsed().as_secs_f64());
        }
        (r, trace, iter_s, cache_bytes)
    }

    /// The old objective evaluation: one fresh FFT per row per call.
    pub fn objective(
        planner: &Planner,
        d: usize,
        cfg: &TimeFreqConfig,
        x: &Mat,
        r: &[f32],
    ) -> f64 {
        let r_spec = real::rfft_full(planner, r);
        let mut bin_err = 0f64;
        for i in 0..x.rows {
            let xf = real::rfft_full(planner, x.row(i));
            let mut yspec: Vec<C64> = xf.iter().zip(&r_spec).map(|(a, b)| *a * *b).collect();
            planner.ifft(&mut yspec);
            for j in 0..d {
                let y = yspec[j].re;
                let b = if j < cfg.k {
                    if y >= 0.0 {
                        1.0
                    } else {
                        -1.0
                    }
                } else {
                    0.0
                };
                let e = b - y;
                bin_err += e * e;
            }
        }
        let ortho: f64 = r_spec.iter().map(|c| (c.norm_sqr() - 1.0).powi(2)).sum();
        bin_err + cfg.lambda * ortho
    }

    fn pair_penalty(planner: &Planner, d: usize, x: &Mat, ps: &PairSet) -> Vec<f64> {
        let mut a = vec![0f64; d];
        let mut add = |i: usize, j: usize, sign: f64| {
            let xi = real::rfft_full(planner, x.row(i));
            let xj = real::rfft_full(planner, x.row(j));
            for l in 0..d {
                a[l] += sign * (xi[l] - xj[l]).norm_sqr();
            }
        };
        for &(i, j) in &ps.similar {
            add(i, j, 1.0);
        }
        for &(i, j) in &ps.dissimilar {
            add(i, j, -1.0);
        }
        a
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fft::real;
    use crate::projections::CirculantProjection;
    use crate::util::rng::Pcg64;

    fn make_data(n: usize, d: usize, seed: u64) -> Mat {
        let mut rng = Pcg64::new(seed);
        let mut x = Mat::randn(n, d, &mut rng);
        for i in 0..n {
            crate::util::l2_normalize(x.row_mut(i));
        }
        x
    }

    #[test]
    fn objective_decreases() {
        for d in [16usize, 30] {
            let x = make_data(40, d, 3);
            let mut rng = Pcg64::new(4);
            let r0 = rng.normal_vec(d);
            let planner = Planner::new();
            let mut opt = TimeFreqOptimizer::new(d, TimeFreqConfig::new(d), planner.clone());
            let cache = SpectrumCache::build(&x, &planner, 1);
            let obj_init = opt.objective(&cache, &r0);
            let r = opt.run_cached(&cache, &r0, None);
            let obj_final = opt.objective(&cache, &r);
            assert!(obj_final < obj_init, "d={d}: {obj_final} !< {obj_init}");
            // Per-step trace values mix old-B binarization error with
            // new-r orthogonality, so trace[0] still reflects the random
            // init's scale; from iteration 1 on the trace must descend.
            let tr = &opt.objective_trace;
            for w in tr[1..].windows(2) {
                assert!(w[1] <= w[0] + 1e-6, "trace not monotone: {w:?}");
            }
        }
    }

    #[test]
    fn learned_spectrum_near_unit_modulus() {
        // With λ large, |r̃_l| → 1 for all bins (R → orthogonal-ish).
        let d = 32;
        let x = make_data(30, d, 7);
        let mut rng = Pcg64::new(8);
        let r0 = rng.normal_vec(d);
        let planner = Planner::new();
        let mut cfg = TimeFreqConfig::new(d);
        cfg.lambda = 100.0;
        let mut opt = TimeFreqOptimizer::new(d, cfg, planner.clone());
        let r = opt.run(&x, &r0, None);
        let spec = real::rfft_full(&planner, &r);
        for c in &spec {
            assert!((c.abs() - 1.0).abs() < 0.2, "|r̃|={}", c.abs());
        }
    }

    #[test]
    fn k_less_than_d_runs_and_descends() {
        let d = 24;
        let x = make_data(30, d, 9);
        let mut rng = Pcg64::new(10);
        let r0 = rng.normal_vec(d);
        let planner = Planner::new();
        let mut opt = TimeFreqOptimizer::new(d, TimeFreqConfig::new(8), planner.clone());
        let cache = SpectrumCache::build(&x, &planner, 1);
        let o0 = opt.objective(&cache, &r0);
        let r = opt.run_cached(&cache, &r0, None);
        assert!(opt.objective(&cache, &r) < o0);
    }

    #[test]
    fn semi_supervised_changes_solution() {
        let d = 16;
        let x = make_data(20, d, 11);
        let mut rng = Pcg64::new(12);
        let r0 = rng.normal_vec(d);
        let planner = Planner::new();
        let pairs = PairSet {
            similar: vec![(0, 1), (2, 3)],
            dissimilar: vec![(4, 5)],
        };
        let mut cfg = TimeFreqConfig::new(d);
        cfg.mu = 0.5;
        let mut opt_ss = TimeFreqOptimizer::new(d, cfg, planner.clone());
        let r_ss = opt_ss.run(&x, &r0, Some(&pairs));
        let mut opt = TimeFreqOptimizer::new(d, TimeFreqConfig::new(d), planner);
        let r_plain = opt.run(&x, &r0, None);
        let diff: f32 = r_ss
            .iter()
            .zip(&r_plain)
            .map(|(a, b)| (a - b).abs())
            .sum();
        assert!(diff > 1e-4, "supervision had no effect");
    }

    #[test]
    fn learned_r_is_real_signal() {
        // The per-bin updates must keep conjugate symmetry so r stays real
        // — verified by round-tripping through the spectrum.
        let d = 20;
        let x = make_data(15, d, 13);
        let mut rng = Pcg64::new(14);
        let r0 = rng.normal_vec(d);
        let planner = Planner::new();
        let mut opt = TimeFreqOptimizer::new(d, TimeFreqConfig::new(d), planner.clone());
        let r = opt.run(&x, &r0, None);
        let spec = real::rfft_full(&planner, &r);
        assert!(real::symmetry_error(&spec) < 1e-6);
    }

    #[test]
    fn cached_objective_equals_reference() {
        // The cache contract: objective() reading the half-spectrum cache
        // computes the same quantity as the old per-row-re-FFT path —
        // equal up to the rounding of the half-size transform.
        for (n, d) in [(25usize, 16usize), (40, 21), (130, 32)] {
            let x = make_data(n, d, 100 + d as u64);
            let mut rng = Pcg64::new(101);
            let r = rng.normal_vec(d);
            let planner = Planner::new();
            let cfg = TimeFreqConfig::new(d.min(12));
            let opt = TimeFreqOptimizer::new(d, cfg.clone(), planner.clone());
            let cache = SpectrumCache::build(&x, &planner, 4);
            let cached = opt.objective(&cache, &r);
            let legacy = reference::objective(&planner, d, &cfg, &x, &r);
            assert!(
                (cached - legacy).abs() <= 1e-9 * legacy.abs().max(1.0),
                "n={n} d={d}: cached {cached} vs legacy {legacy}"
            );
        }
    }

    #[test]
    fn half_spectrum_run_matches_reference_codes() {
        // The half-spectrum engine runs different (half-size) FFT
        // arithmetic, so the learned r agrees with the full-spectrum
        // reference only to rounding — but a trained model must emit
        // *identical binary codes* on a probe set (the full property
        // sweep lives in rust/tests/train_parallel.rs).
        for d in [16usize, 21] {
            let n = 40;
            let x = make_data(n, d, 200 + d as u64);
            let mut rng = Pcg64::new(201);
            let r0 = rng.normal_vec(d);
            let planner = Planner::new();
            let mut cfg = TimeFreqConfig::new(d);
            cfg.iters = 4;
            let (r_legacy, trace_legacy) =
                reference::run(&planner, d, &cfg, &x, &r0, None);
            let mut opt = TimeFreqOptimizer::new(d, cfg, planner.clone());
            let r_new = opt.run(&x, &r0, None);
            for (a, b) in r_new.iter().zip(&r_legacy) {
                assert!((a - b).abs() < 1e-3 * (1.0 + b.abs()), "d={d}: {a} vs {b}");
            }
            for (a, b) in opt.objective_trace.iter().zip(&trace_legacy) {
                assert!((a - b).abs() < 1e-4 * (1.0 + b.abs()), "d={d} trace");
            }
            let signs = vec![1f32; d];
            let p_new = CirculantProjection::new(r_new, signs.clone(), planner.clone());
            let p_leg = CirculantProjection::new(r_legacy, signs, planner);
            let mut qrng = Pcg64::new(500 + d as u64);
            for t in 0..16 {
                let q = qrng.normal_vec(d);
                assert_eq!(p_new.encode(&q, d), p_leg.encode(&q, d), "d={d} probe {t}");
            }
        }
    }

    #[test]
    fn full_cache_reference_matches_legacy_bit_for_bit() {
        // The bench's `full` arm caches the same full spectra the legacy
        // loop recomputes, so the two must agree to the last ulp.
        for d in [16usize, 21] {
            let n = 50;
            let x = make_data(n, d, 250 + d as u64);
            let mut rng = Pcg64::new(251);
            let r0 = rng.normal_vec(d);
            let planner = Planner::new();
            let mut cfg = TimeFreqConfig::new(d);
            cfg.iters = 3;
            let (r_legacy, trace_legacy) =
                reference::run(&planner, d, &cfg, &x, &r0, None);
            let (r_full, trace_full, iter_s, bytes) =
                reference::run_full_cache(&planner, d, &cfg, &x, &r0);
            assert_eq!(bytes, n * d * 16);
            assert_eq!(iter_s.len(), cfg.iters);
            for (a, b) in r_full.iter().zip(&r_legacy) {
                assert_eq!(a.to_bits(), b.to_bits(), "d={d}");
            }
            for (a, b) in trace_full.iter().zip(&trace_legacy) {
                assert_eq!(a.to_bits(), b.to_bits(), "d={d}");
            }
        }
    }

    #[test]
    fn parallel_matches_serial_bit_for_bit() {
        // The deterministic-flag contract, in-module smoke version (the
        // full property sweep lives in rust/tests/train_parallel.rs):
        // thread count must not change a single output bit.
        let d = 24;
        let n = 150; // several DETERMINISTIC_BLOCK blocks
        let x = make_data(n, d, 300);
        let mut rng = Pcg64::new(301);
        let r0 = rng.normal_vec(d);
        let planner = Planner::new();
        let mut cfg = TimeFreqConfig::new(d);
        cfg.iters = 4;
        cfg.deterministic = true;
        cfg.threads = 1;
        let mut serial = TimeFreqOptimizer::new(d, cfg.clone(), planner.clone());
        let r_serial = serial.run(&x, &r0, None);
        cfg.threads = 4;
        let mut par = TimeFreqOptimizer::new(d, cfg, planner);
        let r_par = par.run(&x, &r0, None);
        for (a, b) in r_par.iter().zip(&r_serial) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
        // 150 rows / 64-row blocks = 3 blocks, so 4 requested workers
        // clamp to the 3 the pass can actually use.
        assert_eq!(par.report.threads, 3);
        assert_eq!(serial.report.threads, 1);
    }

    #[test]
    fn budget_tiling_is_bit_identical_to_cached() {
        // The memory budget moves bytes, never results: a run forced to
        // stream block-aligned tiles must reproduce the fully cached run
        // to the last bit — including §6 pair supervision, which the
        // tiled path recomputes per pair.
        let d = 20;
        let n = 200; // 4 deterministic blocks, several tiles
        let x = make_data(n, d, 500);
        let mut rng = Pcg64::new(501);
        let r0 = rng.normal_vec(d);
        let pairs = PairSet {
            similar: vec![(0, 7), (33, 150)],
            dissimilar: vec![(12, 180)],
        };
        let planner = Planner::new();
        let mut cfg = TimeFreqConfig::new(d);
        cfg.iters = 3;
        cfg.threads = 3;
        cfg.mu = 0.5;
        let mut cached = TimeFreqOptimizer::new(d, cfg.clone(), planner.clone());
        let r_cached = cached.run(&x, &r0, Some(&pairs));
        assert_eq!(cached.report.tile_rows, 0);

        // Budget fits 1.5 blocks of rows → tiles of exactly one block.
        let hlen = d / 2 + 1;
        cfg.cache_budget = 96 * hlen * 16;
        let mut tiled = TimeFreqOptimizer::new(d, cfg, planner);
        let r_tiled = tiled.run(&x, &r0, Some(&pairs));
        assert_eq!(tiled.report.tile_rows, DETERMINISTIC_BLOCK);
        assert!(tiled.report.cache_bytes < cached.report.cache_bytes);
        for (a, b) in r_tiled.iter().zip(&r_cached) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
        for (a, b) in tiled.objective_trace.iter().zip(&cached.objective_trace) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
    }

    #[test]
    fn budget_is_honored_without_determinism() {
        // Non-deterministic runs size reduction blocks per thread —
        // which can span the whole corpus — but the tiled path must
        // still tile at DETERMINISTIC_BLOCK granularity or the budget
        // silently becomes a no-op.
        let d = 16;
        let n = 200;
        let x = make_data(n, d, 800);
        let mut rng = Pcg64::new(801);
        let r0 = rng.normal_vec(d);
        let mut cfg = TimeFreqConfig::new(d);
        cfg.iters = 2;
        cfg.deterministic = false;
        cfg.threads = 1; // per-thread block = the whole corpus
        let budget = 96 * (d / 2 + 1) * 16;
        cfg.cache_budget = budget;
        let mut opt = TimeFreqOptimizer::new(d, cfg, Planner::new());
        let _ = opt.run(&x, &r0, None);
        assert_eq!(opt.report.tile_rows, DETERMINISTIC_BLOCK);
        assert!(opt.report.cache_bytes <= budget);
    }

    #[test]
    fn cache_bytes_halved_vs_full_layout() {
        // The acceptance bar: the resident cache is ≤ 0.55× the PR-4
        // full-spectrum layout (16·n·d) at the paper dims.
        for d in [256usize, 1024] {
            let n = 48;
            let x = make_data(n, d, 600 + d as u64);
            let mut rng = Pcg64::new(601);
            let r0 = rng.normal_vec(d);
            let mut cfg = TimeFreqConfig::new(d);
            cfg.iters = 1;
            let mut opt = TimeFreqOptimizer::new(d, cfg, Planner::new());
            let _ = opt.run(&x, &r0, None);
            let full = 16 * n * d;
            assert_eq!(opt.report.cache_bytes, n * (d / 2 + 1) * 16);
            assert!(
                (opt.report.cache_bytes as f64) <= 0.55 * full as f64,
                "d={d}: {} vs full {full}",
                opt.report.cache_bytes
            );
        }
    }

    #[test]
    fn solve_bins_half_matches_full_solver() {
        // On mirrored inputs (m/h mirror, g negates, r̃ conjugates) the
        // half solver must be bit-identical to the full one: x + x and
        // 2·x are the same IEEE value, and every other operation is
        // shared verbatim.
        let mut rng = Pcg64::new(700);
        for d in [16usize, 21] {
            let hlen = d / 2 + 1;
            let mut m_half = vec![0f64; hlen];
            let mut h_half = vec![0f64; hlen];
            let mut g_half = vec![0f64; hlen];
            let mut r_half = vec![C64::ZERO; hlen];
            for l in 0..hlen {
                m_half[l] = rng.next_f64() + 0.1;
                h_half[l] = rng.normal();
                g_half[l] = rng.normal();
                r_half[l] = C64::new(rng.normal(), rng.normal());
            }
            r_half[0] = C64::new(r_half[0].re, 0.0);
            g_half[0] = 0.0;
            if d % 2 == 0 {
                r_half[d / 2] = C64::new(r_half[d / 2].re, 0.0);
                g_half[d / 2] = 0.0;
            }
            let mut m = vec![0f64; d];
            let mut h = vec![0f64; d];
            let mut g = vec![0f64; d];
            let mut r_full = vec![C64::ZERO; d];
            for l in 0..hlen {
                m[l] = m_half[l];
                h[l] = h_half[l];
                g[l] = g_half[l];
                r_full[l] = r_half[l];
                if l >= 1 && d - l > l {
                    m[d - l] = m_half[l];
                    h[d - l] = h_half[l];
                    g[d - l] = -g_half[l];
                    r_full[d - l] = r_half[l].conj();
                }
            }
            let half = solve_bins_half(&m_half, &h_half, &g_half, &r_half, 1.0, d);
            let full = solve_bins(&m, &h, &g, &r_full, 1.0, d);
            for l in 0..hlen {
                assert_eq!(half[l].re.to_bits(), full[l].re.to_bits(), "d={d} l={l} re");
                assert_eq!(half[l].im.to_bits(), full[l].im.to_bits(), "d={d} l={l} im");
            }
        }
    }

    #[test]
    fn report_records_the_run() {
        let d = 16;
        let x = make_data(30, d, 400);
        let mut rng = Pcg64::new(401);
        let r0 = rng.normal_vec(d);
        let mut cfg = TimeFreqConfig::new(d);
        cfg.iters = 3;
        let mut opt = TimeFreqOptimizer::new(d, cfg, Planner::new());
        let _ = opt.run(&x, &r0, None);
        let rep = &opt.report;
        assert_eq!(rep.n, 30);
        assert_eq!(rep.d, d);
        assert_eq!(rep.iters, 3);
        assert_eq!(rep.objective_trace.len(), 3);
        assert_eq!(rep.iter_ms.len(), 3);
        // Half-spectrum layout: ⌊d/2⌋+1 bins per row, 16 bytes each.
        assert_eq!(rep.cache_bytes, 30 * (d / 2 + 1) * 16);
        assert_eq!(rep.tile_rows, 0);
        assert!(rep.total_ms >= 0.0);
    }
}
