//! §4: the time–frequency alternating optimization for CBE-opt.
//!
//! Minimizes  ‖B − XRᵀ‖²_F + λ‖RRᵀ − I‖²_F  s.t. R = circ(r)  by
//! alternating:
//!
//! * **time domain** — B = sign(XRᵀ) (eq. 16; columns ≥ k zeroed for the
//!   k < d heuristic of §4.2), and
//! * **frequency domain** — per-DFT-bin closed-form updates of r̃ = F(r).
//!   The objective decomposes (eqs. 20–22) into a 1-variable quartic for
//!   the DC bin (and Nyquist bin when d is even) and a 2-variable quartic
//!   for each conjugate pair. The 2-variable problem
//!   `min m'(a²+b²) + 2λd(a²+b²−1)² + h'a + g'b` is rotationally symmetric
//!   in (a,b) around the linear tilt (h',g'): at the optimum (a,b) points
//!   along −(h',g'), reducing to a 1-D quartic in the radius ρ, which we
//!   minimize in closed form ([`cubic`](super::cubic)). This is exact, so
//!   the overall objective is monotonically non-increasing — checked by
//!   tests and debug assertions.
//!
//! §6 semi-supervised extension: similar/dissimilar pairs add μ·A to the
//! per-bin quadratic coefficient (M → M + μA), nothing else changes.
//!
//! All per-iteration work is O(n·d log d) — the paper's claimed cost.

use super::cubic::minimize_quartic;
use crate::fft::{real, C64, Planner};
use crate::linalg::Mat;

/// Similar/dissimilar pair supervision for the §6 extension.
#[derive(Clone, Debug, Default)]
pub struct PairSet {
    /// Index pairs that should embed near each other.
    pub similar: Vec<(usize, usize)>,
    /// Index pairs that should embed far apart.
    pub dissimilar: Vec<(usize, usize)>,
}

/// Configuration of the optimization.
#[derive(Clone, Debug)]
pub struct TimeFreqConfig {
    /// λ — weight of the near-orthogonality penalty (paper fixes 1.0).
    pub lambda: f64,
    /// Number of alternating iterations (paper: 5–10 suffice).
    pub iters: usize,
    /// Bits to learn (k ≤ d); trailing B columns are zeroed per §4.2.
    pub k: usize,
    /// μ — weight of the semi-supervised term (0 disables it).
    pub mu: f64,
}

impl TimeFreqConfig {
    pub fn new(k: usize) -> TimeFreqConfig {
        TimeFreqConfig {
            lambda: 1.0,
            iters: 10,
            k,
            mu: 0.0,
        }
    }
}

/// State and result of a CBE-opt training run.
pub struct TimeFreqOptimizer {
    pub cfg: TimeFreqConfig,
    pub d: usize,
    planner: Planner,
    /// Objective value after each iteration (for convergence reporting).
    pub objective_trace: Vec<f64>,
}

impl TimeFreqOptimizer {
    pub fn new(d: usize, cfg: TimeFreqConfig, planner: Planner) -> TimeFreqOptimizer {
        assert!(cfg.k >= 1 && cfg.k <= d);
        TimeFreqOptimizer {
            cfg,
            d,
            planner,
            objective_trace: Vec::new(),
        }
    }

    /// Run the alternating optimization. `x` holds training rows (already
    /// sign-flipped by D). `r0` is the initial circulant vector (CBE-rand
    /// init in the paper). Optional pair supervision. Returns the learned r.
    pub fn run(&mut self, x: &Mat, r0: &[f32], pairs: Option<&PairSet>) -> Vec<f32> {
        let d = self.d;
        let n = x.rows;
        assert_eq!(x.cols, d);
        assert_eq!(r0.len(), d);

        // ---- Precompute M (eq. 17): m_l = Σ_i |F(x_i)_l|², plus μ·A (§6).
        let mut m = vec![0f64; d];
        for i in 0..n {
            let xf = real::rfft_full(&self.planner, x.row(i));
            for (l, c) in xf.iter().enumerate() {
                m[l] += c.norm_sqr();
            }
        }
        if let Some(ps) = pairs {
            if self.cfg.mu != 0.0 {
                let a = self.pair_penalty(x, ps);
                for l in 0..d {
                    m[l] += self.cfg.mu * a[l];
                }
            }
        }

        let mut r = r0.to_vec();
        self.objective_trace.clear();

        for _iter in 0..self.cfg.iters {
            let r_spec = real::rfft_full(&self.planner, &r);

            // ---- Time-domain pass: B = sign(XRᵀ) with cols ≥ k zeroed,
            // and accumulate h, g (eq. 17) in the same sweep.
            let mut h = vec![0f64; d];
            let mut g = vec![0f64; d];
            let mut binarization_err = 0f64; // ‖B − XRᵀ‖²_F for the trace

            let mut bi = vec![0f32; d];
            for i in 0..n {
                let xf = real::rfft_full(&self.planner, x.row(i));
                // y = R x_i via spectral product
                let mut yspec: Vec<C64> = xf
                    .iter()
                    .zip(&r_spec)
                    .map(|(a, b)| *a * *b)
                    .collect();
                self.planner.ifft(&mut yspec);
                for j in 0..d {
                    let y = yspec[j].re;
                    let b = if j < self.cfg.k {
                        if y >= 0.0 {
                            1.0
                        } else {
                            -1.0
                        }
                    } else {
                        0.0
                    };
                    bi[j] = b as f32;
                    let e = b - y;
                    binarization_err += e * e;
                }
                let bf = real::rfft_full(&self.planner, &bi);
                for l in 0..d {
                    // h = −2 Σ Re(x̃)∘Re(b̃) + Im(x̃)∘Im(b̃)
                    h[l] -= 2.0 * (xf[l].re * bf[l].re + xf[l].im * bf[l].im);
                    // g = 2 Σ Im(x̃)∘Re(b̃) − Re(x̃)∘Im(b̃)
                    g[l] += 2.0 * (xf[l].im * bf[l].re - xf[l].re * bf[l].im);
                }
            }

            // ---- Frequency-domain pass: closed-form per-bin minimizers.
            // (λ = 0 would degenerate the quartics; clamp keeps them convex.)
            let lam_d = (self.cfg.lambda * d as f64).max(1e-9);
            let mut spec = vec![C64::ZERO; d];

            // DC bin (eq. 21): min m₀t² + h₀t + λd(t²−1)², t real.
            // = λd·t⁴ + (m₀ − 2λd)t² + h₀t + λd
            let (t0, _) = minimize_quartic(lam_d, m[0] - 2.0 * lam_d, h[0], lam_d);
            spec[0] = C64::new(t0, 0.0);

            // Nyquist bin for even d — same 1-variable form.
            if d % 2 == 0 {
                let l = d / 2;
                let (t, _) = minimize_quartic(lam_d, m[l] - 2.0 * lam_d, h[l], lam_d);
                spec[l] = C64::new(t, 0.0);
            }

            // Conjugate pairs (eq. 22): variables a = Re(r̃_i), b = Im(r̃_i).
            //   f(a,b) = m'(a²+b²) + 2λd(a²+b²−1)² + h'a + g'b
            // with m' = m_i + m_{d−i}, h' = h_i + h_{d−i}, g' = g_i − g_{d−i}.
            // Radial reduction: (a,b) = −ρ·(h',g')/‖(h',g')‖ and minimize
            //   f(ρ) = 2λd·ρ⁴ + (m' − 4λd)ρ² − ‖(h',g')‖ρ  over ρ ∈ R.
            for i in 1..=(d - 1) / 2 {
                let mp = m[i] + m[d - i];
                let hp = h[i] + h[d - i];
                let gp = g[i] - g[d - i];
                let cnorm = (hp * hp + gp * gp).sqrt();
                let a4 = 2.0 * lam_d;
                let a2 = mp - 4.0 * lam_d;
                let (re, im) = if cnorm > 1e-300 {
                    let (rho, _) = minimize_quartic(a4, a2, -cnorm, 2.0 * lam_d);
                    // rho may come out negative if the cubic picked the
                    // mirrored root; fold the sign into the direction.
                    (-rho * hp / cnorm, -rho * gp / cnorm)
                } else {
                    // No linear tilt: pick the radius minimizing the radial
                    // part, direction along previous iterate for stability.
                    let rho2 = ((4.0 * lam_d - mp) / (4.0 * lam_d)).max(0.0);
                    let rho = rho2.sqrt();
                    let prev = r_spec[i];
                    let pn = prev.abs();
                    if pn > 1e-300 {
                        (rho * prev.re / pn, rho * prev.im / pn)
                    } else {
                        (rho, 0.0)
                    }
                };
                spec[i] = C64::new(re, im);
                spec[d - i] = C64::new(re, -im);
            }

            r = real::irfft_full(&self.planner, &spec);

            // ---- Objective for the trace (eq. 15, with the new B fixed
            // implicitly — we log binarization error of the *previous* r
            // plus the orthogonality penalty of the *new* r̃; monotonicity
            // of the true objective is asserted in tests on small cases).
            let ortho: f64 = {
                let mut s = 0f64;
                for c in &spec {
                    let e = c.norm_sqr() - 1.0;
                    s += e * e;
                }
                s
            };
            self.objective_trace
                .push(binarization_err + self.cfg.lambda * ortho);
        }
        r
    }

    /// §6: per-bin penalty a_l = Σ_{M} |F(x_i)_l − F(x_j)_l|² −
    /// Σ_{D} |F(x_i)_l − F(x_j)_l|².
    fn pair_penalty(&self, x: &Mat, ps: &PairSet) -> Vec<f64> {
        let d = self.d;
        let mut a = vec![0f64; d];
        let add = |i: usize, j: usize, sign: f64, a: &mut Vec<f64>| {
            let xi = real::rfft_full(&self.planner, x.row(i));
            let xj = real::rfft_full(&self.planner, x.row(j));
            for l in 0..d {
                a[l] += sign * (xi[l] - xj[l]).norm_sqr();
            }
        };
        for &(i, j) in &ps.similar {
            add(i, j, 1.0, &mut a);
        }
        for &(i, j) in &ps.dissimilar {
            add(i, j, -1.0, &mut a);
        }
        a
    }

    /// Evaluate the full objective (eq. 15) for given r against data x —
    /// used by tests to verify monotone descent.
    pub fn objective(&self, x: &Mat, r: &[f32]) -> f64 {
        let d = self.d;
        let r_spec = real::rfft_full(&self.planner, r);
        let mut bin_err = 0f64;
        for i in 0..x.rows {
            let xf = real::rfft_full(&self.planner, x.row(i));
            let mut yspec: Vec<C64> = xf.iter().zip(&r_spec).map(|(a, b)| *a * *b).collect();
            self.planner.ifft(&mut yspec);
            for j in 0..d {
                let y = yspec[j].re;
                let b = if j < self.cfg.k {
                    if y >= 0.0 {
                        1.0
                    } else {
                        -1.0
                    }
                } else {
                    0.0
                };
                let e = b - y;
                bin_err += e * e;
            }
        }
        let ortho: f64 = r_spec.iter().map(|c| (c.norm_sqr() - 1.0).powi(2)).sum();
        bin_err + self.cfg.lambda * ortho
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg64;

    fn make_data(n: usize, d: usize, seed: u64) -> Mat {
        let mut rng = Pcg64::new(seed);
        let mut x = Mat::randn(n, d, &mut rng);
        for i in 0..n {
            crate::util::l2_normalize(x.row_mut(i));
        }
        x
    }

    #[test]
    fn objective_decreases() {
        for d in [16usize, 30] {
            let x = make_data(40, d, 3);
            let mut rng = Pcg64::new(4);
            let r0 = rng.normal_vec(d);
            let planner = Planner::new();
            let mut opt =
                TimeFreqOptimizer::new(d, TimeFreqConfig::new(d), planner.clone());
            let obj_init = opt.objective(&x, &r0);
            let r = opt.run(&x, &r0, None);
            let obj_final = opt.objective(&x, &r);
            assert!(
                obj_final < obj_init,
                "d={d}: {obj_final} !< {obj_init}"
            );
            // Per-step trace values mix old-B binarization error with
            // new-r orthogonality, so trace[0] still reflects the random
            // init's scale; from iteration 1 on the trace must descend.
            let tr = &opt.objective_trace;
            for w in tr[1..].windows(2) {
                assert!(w[1] <= w[0] + 1e-6, "trace not monotone: {w:?}");
            }
        }
    }

    #[test]
    fn learned_spectrum_near_unit_modulus() {
        // With λ large, |r̃_l| → 1 for all bins (R → orthogonal-ish).
        let d = 32;
        let x = make_data(30, d, 7);
        let mut rng = Pcg64::new(8);
        let r0 = rng.normal_vec(d);
        let planner = Planner::new();
        let mut cfg = TimeFreqConfig::new(d);
        cfg.lambda = 100.0;
        let mut opt = TimeFreqOptimizer::new(d, cfg, planner.clone());
        let r = opt.run(&x, &r0, None);
        let spec = real::rfft_full(&planner, &r);
        for c in &spec {
            assert!((c.abs() - 1.0).abs() < 0.2, "|r̃|={}", c.abs());
        }
    }

    #[test]
    fn k_less_than_d_runs_and_descends() {
        let d = 24;
        let x = make_data(30, d, 9);
        let mut rng = Pcg64::new(10);
        let r0 = rng.normal_vec(d);
        let planner = Planner::new();
        let mut opt = TimeFreqOptimizer::new(d, TimeFreqConfig::new(8), planner);
        let o0 = opt.objective(&x, &r0);
        let r = opt.run(&x, &r0, None);
        assert!(opt.objective(&x, &r) < o0);
    }

    #[test]
    fn semi_supervised_changes_solution() {
        let d = 16;
        let x = make_data(20, d, 11);
        let mut rng = Pcg64::new(12);
        let r0 = rng.normal_vec(d);
        let planner = Planner::new();
        let pairs = PairSet {
            similar: vec![(0, 1), (2, 3)],
            dissimilar: vec![(4, 5)],
        };
        let mut cfg = TimeFreqConfig::new(d);
        cfg.mu = 0.5;
        let mut opt_ss = TimeFreqOptimizer::new(d, cfg, planner.clone());
        let r_ss = opt_ss.run(&x, &r0, Some(&pairs));
        let mut opt = TimeFreqOptimizer::new(d, TimeFreqConfig::new(d), planner);
        let r_plain = opt.run(&x, &r0, None);
        let diff: f32 = r_ss
            .iter()
            .zip(&r_plain)
            .map(|(a, b)| (a - b).abs())
            .sum();
        assert!(diff > 1e-4, "supervision had no effect");
    }

    #[test]
    fn learned_r_is_real_signal() {
        // The per-bin updates must keep conjugate symmetry so r stays real
        // — verified by round-tripping through the spectrum.
        let d = 20;
        let x = make_data(15, d, 13);
        let mut rng = Pcg64::new(14);
        let r0 = rng.normal_vec(d);
        let planner = Planner::new();
        let mut opt = TimeFreqOptimizer::new(d, TimeFreqConfig::new(d), planner.clone());
        let r = opt.run(&x, &r0, None);
        let spec = real::rfft_full(&planner, &r);
        assert!(real::symmetry_error(&spec) < 1e-6);
    }
}
