//! §4: the time–frequency alternating optimization for CBE-opt.
//!
//! Minimizes  ‖B − XRᵀ‖²_F + λ‖RRᵀ − I‖²_F  s.t. R = circ(r)  by
//! alternating:
//!
//! * **time domain** — B = sign(XRᵀ) (eq. 16; columns ≥ k zeroed for the
//!   k < d heuristic of §4.2), and
//! * **frequency domain** — per-DFT-bin closed-form updates of r̃ = F(r).
//!   The objective decomposes (eqs. 20–22) into a 1-variable quartic for
//!   the DC bin (and Nyquist bin when d is even) and a 2-variable quartic
//!   for each conjugate pair. The 2-variable problem
//!   `min m'(a²+b²) + 2λd(a²+b²−1)² + h'a + g'b` is rotationally symmetric
//!   in (a,b) around the linear tilt (h',g'): at the optimum (a,b) points
//!   along −(h',g'), reducing to a 1-D quartic in the radius ρ, which we
//!   minimize in closed form ([`cubic`](super::cubic)). This is exact, so
//!   the overall objective is monotonically non-increasing — checked by
//!   tests and debug assertions.
//!
//! §6 semi-supervised extension: similar/dissimilar pairs add μ·A to the
//! per-bin quadratic coefficient (M → M + μA), nothing else changes.
//!
//! # The spectrum cache
//!
//! Every quantity the optimization reads from the data — M (eq. 17), the
//! per-iteration products F(xᵢ) ∘ r̃, the h/g accumulators, the §6 pair
//! penalty, and the full objective — depends on the rows only through
//! their spectra F(xᵢ). Those spectra never change across iterations, so
//! [`SpectrumCache`] computes all of them exactly once (in parallel) and
//! every later pass reads the cache: per iteration the trainer runs 2n
//! FFTs (IFFT of the product, FFT of the new B rows) instead of the 3n+
//! of the old per-row-re-FFT loop, and `objective`/`pair_penalty` run 0.
//! Cache memory is 16·n·d bytes (one `C64` per row element).
//!
//! # Threading and determinism
//!
//! The per-row time-domain step and the per-bin frequency accumulation
//! (h, g, M) fan out across core-capped `std::thread::scope` threads,
//! built directly on the PR-3 substrate: one immutable `Arc<Plan>` shared
//! by every worker, all mutable state in caller-owned [`FftScratch`]-based
//! worker buffers. Reductions are **blocked**: rows are cut into
//! fixed-order blocks, each block accumulates its partial (h, g, err)
//! serially in row order, and partials are folded in ascending block
//! order after the join. With [`TimeFreqConfig::deterministic`] set the
//! block size is a fixed constant, so the reduction tree — and therefore
//! every output bit — is identical at *any* thread count, including the
//! serial cutover (work below the calibrated
//! [`crate::tune::min_parallel_work`] threshold runs the same blocked
//! loop on one thread). With the flag off, blocks are sized per thread
//! (fewer partials; still deterministic for a fixed thread count).

use super::cubic::minimize_quartic;
use crate::fft::{C64, Dir, FftScratch, Plan, Planner};
use crate::linalg::Mat;
use std::sync::Arc;
use std::time::Instant;

/// Fixed reduction-block size (rows) under
/// [`TimeFreqConfig::deterministic`]: small enough that n ≫ block keeps
/// every core busy, large enough that partial buffers stay negligible.
pub const DETERMINISTIC_BLOCK: usize = 64;

/// Similar/dissimilar pair supervision for the §6 extension.
#[derive(Clone, Debug, Default)]
pub struct PairSet {
    /// Index pairs that should embed near each other.
    pub similar: Vec<(usize, usize)>,
    /// Index pairs that should embed far apart.
    pub dissimilar: Vec<(usize, usize)>,
}

/// Configuration of the optimization.
#[derive(Clone, Debug)]
pub struct TimeFreqConfig {
    /// λ — weight of the near-orthogonality penalty (paper fixes 1.0).
    pub lambda: f64,
    /// Number of alternating iterations (paper: 5–10 suffice).
    pub iters: usize,
    /// Bits to learn (k ≤ d); trailing B columns are zeroed per §4.2.
    pub k: usize,
    /// μ — weight of the semi-supervised term (0 disables it).
    pub mu: f64,
    /// Worker threads for the row fan-out. 0 = auto: all cores when the
    /// total work n·d clears [`crate::tune::min_parallel_work`], else
    /// serial. An explicit count bypasses the work gate (the caller — a
    /// parity test, a bench — knows what it wants).
    pub threads: usize,
    /// Fixed-block reductions: outputs are bit-identical at any thread
    /// count (see module docs). Costs a few extra partial buffers.
    pub deterministic: bool,
}

impl TimeFreqConfig {
    pub fn new(k: usize) -> TimeFreqConfig {
        TimeFreqConfig {
            lambda: 1.0,
            iters: 10,
            k,
            mu: 0.0,
            threads: 0,
            deterministic: true,
        }
    }
}

/// Convergence + performance record of one training run.
#[derive(Clone, Debug, Default)]
pub struct TrainReport {
    /// Training rows.
    pub n: usize,
    /// Feature dimension.
    pub d: usize,
    /// Iterations run.
    pub iters: usize,
    /// Worker threads the row fan-out actually used (1 = serial
    /// cutover; never exceeds the reduction-block count, so a short
    /// corpus reports the real parallelism, not the requested one).
    pub threads: usize,
    /// Whether fixed-block (thread-count-invariant) reductions were on.
    pub deterministic: bool,
    /// Objective value after each iteration.
    pub objective_trace: Vec<f64>,
    /// Wall milliseconds per iteration.
    pub iter_ms: Vec<f64>,
    /// Total wall milliseconds (including the spectrum-cache build when
    /// the run built one).
    pub total_ms: f64,
    /// Bytes held by the row-spectrum cache during the run.
    pub spectrum_cache_bytes: usize,
}

/// All row spectra F(xᵢ), computed once and shared by every pass of the
/// optimization ([`TimeFreqOptimizer::run_cached`],
/// [`TimeFreqOptimizer::objective`], [`TimeFreqOptimizer::pair_penalty`]).
/// Row-major `n × d` complex matrix; 16·n·d bytes.
pub struct SpectrumCache {
    /// Rows cached.
    pub n: usize,
    /// Spectrum length (= feature dimension).
    pub d: usize,
    data: Vec<C64>,
}

impl SpectrumCache {
    /// Transform every row of `x` once, fanning rows across up to
    /// `threads` scoped workers (each row is independent, so the build is
    /// bit-exact at any thread count).
    pub fn build(x: &Mat, planner: &Planner, threads: usize) -> SpectrumCache {
        let n = x.rows;
        let d = x.cols;
        let plan = planner.plan(d);
        let mut data = vec![C64::ZERO; n * d];
        let threads = threads.clamp(1, n.max(1));
        let fill_rows = |lo: usize, out: &mut [C64], scratch: &mut FftScratch| {
            for (r, row_out) in out.chunks_mut(d).enumerate() {
                for (c, v) in row_out.iter_mut().zip(x.row(lo + r)) {
                    *c = C64::new(*v as f64, 0.0);
                }
                plan.transform_with(row_out, Dir::Forward, scratch);
            }
        };
        if threads <= 1 {
            fill_rows(0, &mut data[..], &mut FftScratch::new());
        } else {
            let rpt = n.div_ceil(threads);
            std::thread::scope(|scope| {
                for (t, chunk) in data.chunks_mut(rpt * d).enumerate() {
                    let fill_rows = &fill_rows;
                    scope.spawn(move || {
                        fill_rows(t * rpt, chunk, &mut FftScratch::new());
                    });
                }
            });
        }
        SpectrumCache { n, d, data }
    }

    /// The cached spectrum of row i (len d).
    #[inline]
    pub fn row(&self, i: usize) -> &[C64] {
        &self.data[i * self.d..(i + 1) * self.d]
    }

    /// Cache footprint in bytes.
    pub fn bytes(&self) -> usize {
        self.data.len() * std::mem::size_of::<C64>()
    }
}

/// State and result of a CBE-opt training run.
pub struct TimeFreqOptimizer {
    pub cfg: TimeFreqConfig,
    pub d: usize,
    planner: Planner,
    plan: Arc<Plan>,
    /// Objective value after each iteration (for convergence reporting).
    pub objective_trace: Vec<f64>,
    /// Convergence + performance record of the last run.
    pub report: TrainReport,
}

impl TimeFreqOptimizer {
    pub fn new(d: usize, cfg: TimeFreqConfig, planner: Planner) -> TimeFreqOptimizer {
        assert!(cfg.k >= 1 && cfg.k <= d);
        let plan = planner.plan(d);
        TimeFreqOptimizer {
            cfg,
            d,
            planner,
            plan,
            objective_trace: Vec::new(),
            report: TrainReport::default(),
        }
    }

    /// Worker threads for a pass over `n` rows: an explicit
    /// `cfg.threads` wins; auto consults the calibrated work threshold.
    fn fanout_threads(&self, n: usize) -> usize {
        if n == 0 {
            return 1;
        }
        if self.cfg.threads != 0 {
            return self.cfg.threads.min(n);
        }
        let cores = std::thread::available_parallelism()
            .map(|p| p.get())
            .unwrap_or(1);
        if cores <= 1 || n * self.d < crate::tune::min_parallel_work() {
            1
        } else {
            cores.min(n)
        }
    }

    /// Reduction-block size (rows) for blocked accumulations.
    fn block_rows(&self, n: usize, threads: usize) -> usize {
        if self.cfg.deterministic {
            DETERMINISTIC_BLOCK
        } else {
            n.div_ceil(threads.max(1)).max(1)
        }
    }

    /// Run the alternating optimization. `x` holds training rows (already
    /// sign-flipped by D). `r0` is the initial circulant vector (CBE-rand
    /// init in the paper). Optional pair supervision. Returns the learned
    /// r. Builds a throwaway [`SpectrumCache`]; callers that already hold
    /// one (or need it afterwards for [`TimeFreqOptimizer::objective`])
    /// should use [`TimeFreqOptimizer::run_cached`].
    pub fn run(&mut self, x: &Mat, r0: &[f32], pairs: Option<&PairSet>) -> Vec<f32> {
        assert_eq!(x.cols, self.d);
        let t0 = Instant::now();
        let cache = SpectrumCache::build(x, &self.planner, self.fanout_threads(x.rows));
        let cache_ms = t0.elapsed().as_secs_f64() * 1e3;
        let r = self.run_cached(&cache, r0, pairs);
        self.report.total_ms += cache_ms;
        r
    }

    /// The optimization loop proper, reading row spectra from `cache`.
    pub fn run_cached(
        &mut self,
        cache: &SpectrumCache,
        r0: &[f32],
        pairs: Option<&PairSet>,
    ) -> Vec<f32> {
        let d = self.d;
        let n = cache.n;
        assert_eq!(cache.d, d);
        assert_eq!(r0.len(), d);

        let t_run = Instant::now();
        let requested = self.fanout_threads(n);
        let block = self.block_rows(n, requested);
        // What the blocked passes can actually use (≤ one per block) —
        // recorded in the report so it never overstates the fan-out.
        let threads = effective_threads(requested, n, block);

        // ---- Precompute M (eq. 17): m_l = Σ_i |F(x_i)_l|², plus μ·A (§6).
        let mut m = accumulate_m(cache, block, threads);
        if let Some(ps) = pairs {
            if self.cfg.mu != 0.0 {
                let a = self.pair_penalty(cache, ps);
                for l in 0..d {
                    m[l] += self.cfg.mu * a[l];
                }
            }
        }

        let mut r = r0.to_vec();
        self.objective_trace.clear();
        let mut iter_ms = Vec::with_capacity(self.cfg.iters);
        let mut scratch = FftScratch::new();

        for _iter in 0..self.cfg.iters {
            let t_iter = Instant::now();
            let mut r_spec: Vec<C64> = r.iter().map(|v| C64::new(*v as f64, 0.0)).collect();
            self.plan.transform_with(&mut r_spec, Dir::Forward, &mut scratch);

            // ---- Time-domain pass: B = sign(XRᵀ) with cols ≥ k zeroed,
            // h/g (eq. 17) accumulated per frequency bin in the same
            // sweep — fanned across the row blocks.
            let (h, g, binarization_err) =
                time_domain_pass(cache, &r_spec, self.cfg.k, &self.plan, block, threads);

            // ---- Frequency-domain pass: closed-form per-bin minimizers.
            let spec = solve_bins(&m, &h, &g, &r_spec, self.cfg.lambda, d);

            let mut buf = spec.clone();
            self.plan.transform_with(&mut buf, Dir::Inverse, &mut scratch);
            r = buf.iter().map(|c| c.re as f32).collect();

            // ---- Objective for the trace (eq. 15, with the new B fixed
            // implicitly — we log binarization error of the *previous* r
            // plus the orthogonality penalty of the *new* r̃; monotonicity
            // of the true objective is asserted in tests on small cases).
            let ortho: f64 = spec.iter().map(|c| (c.norm_sqr() - 1.0).powi(2)).sum();
            self.objective_trace
                .push(binarization_err + self.cfg.lambda * ortho);
            iter_ms.push(t_iter.elapsed().as_secs_f64() * 1e3);
        }

        self.report = TrainReport {
            n,
            d,
            iters: self.cfg.iters,
            threads,
            deterministic: self.cfg.deterministic,
            objective_trace: self.objective_trace.clone(),
            iter_ms,
            total_ms: t_run.elapsed().as_secs_f64() * 1e3,
            spectrum_cache_bytes: cache.bytes(),
        };
        r
    }

    /// §6: per-bin penalty a_l = Σ_{M} |F(x_i)_l − F(x_j)_l|² −
    /// Σ_{D} |F(x_i)_l − F(x_j)_l|². Reads the shared spectrum cache —
    /// no FFTs at all (the old path re-transformed both rows per pair).
    pub fn pair_penalty(&self, cache: &SpectrumCache, ps: &PairSet) -> Vec<f64> {
        let d = self.d;
        let mut a = vec![0f64; d];
        let mut add = |i: usize, j: usize, sign: f64| {
            let xi = cache.row(i);
            let xj = cache.row(j);
            for l in 0..d {
                a[l] += sign * (xi[l] - xj[l]).norm_sqr();
            }
        };
        for &(i, j) in &ps.similar {
            add(i, j, 1.0);
        }
        for &(i, j) in &ps.dissimilar {
            add(i, j, -1.0);
        }
        a
    }

    /// Evaluate the full objective (eq. 15) for given r against the
    /// cached row spectra — used by tests to verify monotone descent and
    /// by the equality test against [`reference::objective`]. Zero FFTs
    /// over the data (only r's forward transform and n inverse
    /// transforms of the spectral product).
    pub fn objective(&self, cache: &SpectrumCache, r: &[f32]) -> f64 {
        let d = self.d;
        assert_eq!(cache.d, d);
        let mut scratch = FftScratch::new();
        let mut r_spec: Vec<C64> = r.iter().map(|v| C64::new(*v as f64, 0.0)).collect();
        self.plan.transform_with(&mut r_spec, Dir::Forward, &mut scratch);
        let mut bin_err = 0f64;
        let mut yspec = vec![C64::ZERO; d];
        for i in 0..cache.n {
            yspec.copy_from_slice(cache.row(i));
            for (y, rs) in yspec.iter_mut().zip(&r_spec) {
                *y = *y * *rs;
            }
            self.plan.transform_with(&mut yspec, Dir::Inverse, &mut scratch);
            for j in 0..d {
                let y = yspec[j].re;
                let b = if j < self.cfg.k {
                    if y >= 0.0 {
                        1.0
                    } else {
                        -1.0
                    }
                } else {
                    0.0
                };
                let e = b - y;
                bin_err += e * e;
            }
        }
        let ortho: f64 = r_spec.iter().map(|c| (c.norm_sqr() - 1.0).powi(2)).sum();
        bin_err + self.cfg.lambda * ortho
    }
}

// ------------------------------------------------------------------ passes

/// Per-block partial of the time-domain sweep.
struct PassAccum {
    h: Vec<f64>,
    g: Vec<f64>,
    err: f64,
}

impl PassAccum {
    fn new(d: usize) -> PassAccum {
        PassAccum {
            h: vec![0f64; d],
            g: vec![0f64; d],
            err: 0.0,
        }
    }
}

/// Per-worker mutable state of the time-domain sweep.
struct PassState {
    /// Spectral product / time-domain projection buffer, len d.
    yspec: Vec<C64>,
    /// Complex buffer for FFT(bᵢ), len d.
    cplx: Vec<C64>,
    /// Binarized row bᵢ, len d.
    bi: Vec<f32>,
    fft: FftScratch,
}

impl PassState {
    fn new(d: usize) -> PassState {
        PassState {
            yspec: vec![C64::ZERO; d],
            cplx: vec![C64::ZERO; d],
            bi: vec![0f32; d],
            fft: FftScratch::new(),
        }
    }
}

/// Accumulate rows [lo, hi) of the time-domain sweep into `acc`,
/// strictly in ascending row order (the in-block reduction order every
/// mode shares).
#[allow(clippy::too_many_arguments)]
fn pass_rows(
    cache: &SpectrumCache,
    r_spec: &[C64],
    k: usize,
    plan: &Plan,
    lo: usize,
    hi: usize,
    acc: &mut PassAccum,
    st: &mut PassState,
) {
    let d = cache.d;
    for i in lo..hi {
        let xf = cache.row(i);
        // y = R x_i via spectral product on the cached spectrum.
        st.yspec.copy_from_slice(xf);
        for (y, rs) in st.yspec.iter_mut().zip(r_spec) {
            *y = *y * *rs;
        }
        plan.transform_with(&mut st.yspec, Dir::Inverse, &mut st.fft);
        for j in 0..d {
            let y = st.yspec[j].re;
            let b = if j < k {
                if y >= 0.0 {
                    1.0
                } else {
                    -1.0
                }
            } else {
                0.0
            };
            st.bi[j] = b as f32;
            let e = b - y;
            acc.err += e * e;
        }
        for (c, v) in st.cplx.iter_mut().zip(st.bi.iter()) {
            *c = C64::new(*v as f64, 0.0);
        }
        plan.transform_with(&mut st.cplx, Dir::Forward, &mut st.fft);
        for l in 0..d {
            // h = −2 Σ Re(x̃)∘Re(b̃) + Im(x̃)∘Im(b̃)
            acc.h[l] -= 2.0 * (xf[l].re * st.cplx[l].re + xf[l].im * st.cplx[l].im);
            // g = 2 Σ Im(x̃)∘Re(b̃) − Re(x̃)∘Im(b̃)
            acc.g[l] += 2.0 * (xf[l].im * st.cplx[l].re - xf[l].re * st.cplx[l].im);
        }
    }
}

/// Blocks (and therefore reduction-tree shape) for `n` rows cut into
/// `block`-row blocks.
fn block_count(n: usize, block: usize) -> usize {
    n.div_ceil(block.max(1)).max(1)
}

/// Worker threads a blocked pass can actually use (never more than one
/// per block) — also what [`TrainReport::threads`] records.
fn effective_threads(threads: usize, n: usize, block: usize) -> usize {
    threads.clamp(1, block_count(n, block))
}

/// The one blocked fan-out behind every trainer reduction: rows [0, n)
/// are cut into `block`-row blocks, each block accumulates into its own
/// slot (`body` is called with the block's [lo, hi) row range), and
/// contiguous runs of blocks go to scoped worker threads, each with its
/// own `new_state()` worker state. Returns the per-block partials in
/// block order — the caller folds them 0..nblocks, so the reduction
/// tree depends only on `block`, never on the thread count. Keeping the
/// partition/spawn/fold discipline in exactly one place is what makes
/// the determinism contract a property of the module, not of each pass.
fn blocked_partials<A: Send, S>(
    n: usize,
    block: usize,
    threads: usize,
    new_accum: impl Fn() -> A + Sync,
    new_state: impl Fn() -> S + Sync,
    body: impl Fn(usize, usize, &mut A, &mut S) + Sync,
) -> Vec<A> {
    let block = block.max(1);
    let nblocks = block_count(n, block);
    let mut partials: Vec<A> = (0..nblocks).map(|_| new_accum()).collect();
    let threads = effective_threads(threads, n, block);
    let run_blocks = |first_block: usize, slots: &mut [A]| {
        let mut st = new_state();
        for (s, acc) in slots.iter_mut().enumerate() {
            let b = first_block + s;
            body(b * block, ((b + 1) * block).min(n), acc, &mut st);
        }
    };
    if threads <= 1 {
        run_blocks(0, &mut partials[..]);
    } else {
        let bpt = nblocks.div_ceil(threads);
        std::thread::scope(|scope| {
            for (t, chunk) in partials.chunks_mut(bpt).enumerate() {
                let run_blocks = &run_blocks;
                scope.spawn(move || run_blocks(t * bpt, chunk));
            }
        });
    }
    partials
}

/// The parallel time-domain sweep, as a blocked reduction over
/// [`PassAccum`] partials.
fn time_domain_pass(
    cache: &SpectrumCache,
    r_spec: &[C64],
    k: usize,
    plan: &Plan,
    block: usize,
    threads: usize,
) -> (Vec<f64>, Vec<f64>, f64) {
    let d = cache.d;
    let partials = blocked_partials(
        cache.n,
        block,
        threads,
        || PassAccum::new(d),
        || PassState::new(d),
        |lo, hi, acc: &mut PassAccum, st: &mut PassState| {
            pass_rows(cache, r_spec, k, plan, lo, hi, acc, st);
        },
    );
    let mut h = vec![0f64; d];
    let mut g = vec![0f64; d];
    let mut err = 0f64;
    for p in &partials {
        for l in 0..d {
            h[l] += p.h[l];
            g[l] += p.g[l];
        }
        err += p.err;
    }
    (h, g, err)
}

/// Blocked-parallel M accumulation: m_l = Σ_i |F(x_i)_l|², same
/// reduction discipline as [`time_domain_pass`].
fn accumulate_m(cache: &SpectrumCache, block: usize, threads: usize) -> Vec<f64> {
    let d = cache.d;
    let partials = blocked_partials(
        cache.n,
        block,
        threads,
        || vec![0f64; d],
        || (),
        |lo, hi, acc: &mut Vec<f64>, _: &mut ()| {
            for i in lo..hi {
                for (l, c) in cache.row(i).iter().enumerate() {
                    acc[l] += c.norm_sqr();
                }
            }
        },
    );
    let mut m = vec![0f64; d];
    for p in &partials {
        for l in 0..d {
            m[l] += p[l];
        }
    }
    m
}

/// The frequency-domain pass: closed-form per-bin minimizers given the
/// accumulated (M, h, g) and the previous spectrum (for the tilt-free
/// tie-break). Shared verbatim by the trainer and [`reference`] so the
/// two paths can only diverge in how they *accumulate*, never in how
/// they solve. (λ = 0 would degenerate the quartics; clamp keeps them
/// convex.)
fn solve_bins(
    m: &[f64],
    h: &[f64],
    g: &[f64],
    r_spec: &[C64],
    lambda: f64,
    d: usize,
) -> Vec<C64> {
    let lam_d = (lambda * d as f64).max(1e-9);
    let mut spec = vec![C64::ZERO; d];

    // DC bin (eq. 21): min m₀t² + h₀t + λd(t²−1)², t real.
    // = λd·t⁴ + (m₀ − 2λd)t² + h₀t + λd
    let (t0, _) = minimize_quartic(lam_d, m[0] - 2.0 * lam_d, h[0], lam_d);
    spec[0] = C64::new(t0, 0.0);

    // Nyquist bin for even d — same 1-variable form.
    if d % 2 == 0 {
        let l = d / 2;
        let (t, _) = minimize_quartic(lam_d, m[l] - 2.0 * lam_d, h[l], lam_d);
        spec[l] = C64::new(t, 0.0);
    }

    // Conjugate pairs (eq. 22): variables a = Re(r̃_i), b = Im(r̃_i).
    //   f(a,b) = m'(a²+b²) + 2λd(a²+b²−1)² + h'a + g'b
    // with m' = m_i + m_{d−i}, h' = h_i + h_{d−i}, g' = g_i − g_{d−i}.
    // Radial reduction: (a,b) = −ρ·(h',g')/‖(h',g')‖ and minimize
    //   f(ρ) = 2λd·ρ⁴ + (m' − 4λd)ρ² − ‖(h',g')‖ρ  over ρ ∈ R.
    for i in 1..=(d - 1) / 2 {
        let mp = m[i] + m[d - i];
        let hp = h[i] + h[d - i];
        let gp = g[i] - g[d - i];
        let cnorm = (hp * hp + gp * gp).sqrt();
        let a4 = 2.0 * lam_d;
        let a2 = mp - 4.0 * lam_d;
        let (re, im) = if cnorm > 1e-300 {
            let (rho, _) = minimize_quartic(a4, a2, -cnorm, 2.0 * lam_d);
            // rho may come out negative if the cubic picked the
            // mirrored root; fold the sign into the direction.
            (-rho * hp / cnorm, -rho * gp / cnorm)
        } else {
            // No linear tilt: pick the radius minimizing the radial
            // part, direction along previous iterate for stability.
            let rho2 = ((4.0 * lam_d - mp) / (4.0 * lam_d)).max(0.0);
            let rho = rho2.sqrt();
            let prev = r_spec[i];
            let pn = prev.abs();
            if pn > 1e-300 {
                (rho * prev.re / pn, rho * prev.im / pn)
            } else {
                (rho, 0.0)
            }
        };
        spec[i] = C64::new(re, im);
        spec[d - i] = C64::new(re, -im);
    }
    spec
}

// --------------------------------------------------------------- reference

/// The pre-spectrum-cache serial trainer, kept verbatim as the
/// measurement baseline for `cargo bench --bench train_throughput` and
/// as the equality oracle for the cache refactor's tests: it recomputes
/// `F(xᵢ)` for every row in every iteration (and again in every
/// objective evaluation), exactly like the old `TimeFreqOptimizer`.
/// Never use it to train — it exists to be compared against.
pub mod reference {
    use super::*;
    use crate::fft::real;

    /// The old serial run loop (per-row re-FFT everywhere). Returns the
    /// learned r and the objective trace.
    pub fn run(
        planner: &Planner,
        d: usize,
        cfg: &TimeFreqConfig,
        x: &Mat,
        r0: &[f32],
        pairs: Option<&PairSet>,
    ) -> (Vec<f32>, Vec<f64>) {
        let n = x.rows;
        assert_eq!(x.cols, d);
        assert_eq!(r0.len(), d);

        let mut m = vec![0f64; d];
        for i in 0..n {
            let xf = real::rfft_full(planner, x.row(i));
            for (l, c) in xf.iter().enumerate() {
                m[l] += c.norm_sqr();
            }
        }
        if let Some(ps) = pairs {
            if cfg.mu != 0.0 {
                let a = pair_penalty(planner, d, x, ps);
                for l in 0..d {
                    m[l] += cfg.mu * a[l];
                }
            }
        }

        let mut r = r0.to_vec();
        let mut trace = Vec::new();

        for _iter in 0..cfg.iters {
            let r_spec = real::rfft_full(planner, &r);
            let mut h = vec![0f64; d];
            let mut g = vec![0f64; d];
            let mut binarization_err = 0f64;

            let mut bi = vec![0f32; d];
            for i in 0..n {
                let xf = real::rfft_full(planner, x.row(i));
                let mut yspec: Vec<C64> =
                    xf.iter().zip(&r_spec).map(|(a, b)| *a * *b).collect();
                planner.ifft(&mut yspec);
                for j in 0..d {
                    let y = yspec[j].re;
                    let b = if j < cfg.k {
                        if y >= 0.0 {
                            1.0
                        } else {
                            -1.0
                        }
                    } else {
                        0.0
                    };
                    bi[j] = b as f32;
                    let e = b - y;
                    binarization_err += e * e;
                }
                let bf = real::rfft_full(planner, &bi);
                for l in 0..d {
                    h[l] -= 2.0 * (xf[l].re * bf[l].re + xf[l].im * bf[l].im);
                    g[l] += 2.0 * (xf[l].im * bf[l].re - xf[l].re * bf[l].im);
                }
            }

            let spec = solve_bins(&m, &h, &g, &r_spec, cfg.lambda, d);
            r = real::irfft_full(planner, &spec);

            let ortho: f64 = spec.iter().map(|c| (c.norm_sqr() - 1.0).powi(2)).sum();
            trace.push(binarization_err + cfg.lambda * ortho);
        }
        (r, trace)
    }

    /// The old objective evaluation: one fresh FFT per row per call.
    pub fn objective(
        planner: &Planner,
        d: usize,
        cfg: &TimeFreqConfig,
        x: &Mat,
        r: &[f32],
    ) -> f64 {
        let r_spec = real::rfft_full(planner, r);
        let mut bin_err = 0f64;
        for i in 0..x.rows {
            let xf = real::rfft_full(planner, x.row(i));
            let mut yspec: Vec<C64> = xf.iter().zip(&r_spec).map(|(a, b)| *a * *b).collect();
            planner.ifft(&mut yspec);
            for j in 0..d {
                let y = yspec[j].re;
                let b = if j < cfg.k {
                    if y >= 0.0 {
                        1.0
                    } else {
                        -1.0
                    }
                } else {
                    0.0
                };
                let e = b - y;
                bin_err += e * e;
            }
        }
        let ortho: f64 = r_spec.iter().map(|c| (c.norm_sqr() - 1.0).powi(2)).sum();
        bin_err + cfg.lambda * ortho
    }

    fn pair_penalty(planner: &Planner, d: usize, x: &Mat, ps: &PairSet) -> Vec<f64> {
        let mut a = vec![0f64; d];
        let mut add = |i: usize, j: usize, sign: f64| {
            let xi = real::rfft_full(planner, x.row(i));
            let xj = real::rfft_full(planner, x.row(j));
            for l in 0..d {
                a[l] += sign * (xi[l] - xj[l]).norm_sqr();
            }
        };
        for &(i, j) in &ps.similar {
            add(i, j, 1.0);
        }
        for &(i, j) in &ps.dissimilar {
            add(i, j, -1.0);
        }
        a
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fft::real;
    use crate::util::rng::Pcg64;

    fn make_data(n: usize, d: usize, seed: u64) -> Mat {
        let mut rng = Pcg64::new(seed);
        let mut x = Mat::randn(n, d, &mut rng);
        for i in 0..n {
            crate::util::l2_normalize(x.row_mut(i));
        }
        x
    }

    #[test]
    fn objective_decreases() {
        for d in [16usize, 30] {
            let x = make_data(40, d, 3);
            let mut rng = Pcg64::new(4);
            let r0 = rng.normal_vec(d);
            let planner = Planner::new();
            let mut opt = TimeFreqOptimizer::new(d, TimeFreqConfig::new(d), planner.clone());
            let cache = SpectrumCache::build(&x, &planner, 1);
            let obj_init = opt.objective(&cache, &r0);
            let r = opt.run_cached(&cache, &r0, None);
            let obj_final = opt.objective(&cache, &r);
            assert!(obj_final < obj_init, "d={d}: {obj_final} !< {obj_init}");
            // Per-step trace values mix old-B binarization error with
            // new-r orthogonality, so trace[0] still reflects the random
            // init's scale; from iteration 1 on the trace must descend.
            let tr = &opt.objective_trace;
            for w in tr[1..].windows(2) {
                assert!(w[1] <= w[0] + 1e-6, "trace not monotone: {w:?}");
            }
        }
    }

    #[test]
    fn learned_spectrum_near_unit_modulus() {
        // With λ large, |r̃_l| → 1 for all bins (R → orthogonal-ish).
        let d = 32;
        let x = make_data(30, d, 7);
        let mut rng = Pcg64::new(8);
        let r0 = rng.normal_vec(d);
        let planner = Planner::new();
        let mut cfg = TimeFreqConfig::new(d);
        cfg.lambda = 100.0;
        let mut opt = TimeFreqOptimizer::new(d, cfg, planner.clone());
        let r = opt.run(&x, &r0, None);
        let spec = real::rfft_full(&planner, &r);
        for c in &spec {
            assert!((c.abs() - 1.0).abs() < 0.2, "|r̃|={}", c.abs());
        }
    }

    #[test]
    fn k_less_than_d_runs_and_descends() {
        let d = 24;
        let x = make_data(30, d, 9);
        let mut rng = Pcg64::new(10);
        let r0 = rng.normal_vec(d);
        let planner = Planner::new();
        let mut opt = TimeFreqOptimizer::new(d, TimeFreqConfig::new(8), planner.clone());
        let cache = SpectrumCache::build(&x, &planner, 1);
        let o0 = opt.objective(&cache, &r0);
        let r = opt.run_cached(&cache, &r0, None);
        assert!(opt.objective(&cache, &r) < o0);
    }

    #[test]
    fn semi_supervised_changes_solution() {
        let d = 16;
        let x = make_data(20, d, 11);
        let mut rng = Pcg64::new(12);
        let r0 = rng.normal_vec(d);
        let planner = Planner::new();
        let pairs = PairSet {
            similar: vec![(0, 1), (2, 3)],
            dissimilar: vec![(4, 5)],
        };
        let mut cfg = TimeFreqConfig::new(d);
        cfg.mu = 0.5;
        let mut opt_ss = TimeFreqOptimizer::new(d, cfg, planner.clone());
        let r_ss = opt_ss.run(&x, &r0, Some(&pairs));
        let mut opt = TimeFreqOptimizer::new(d, TimeFreqConfig::new(d), planner);
        let r_plain = opt.run(&x, &r0, None);
        let diff: f32 = r_ss
            .iter()
            .zip(&r_plain)
            .map(|(a, b)| (a - b).abs())
            .sum();
        assert!(diff > 1e-4, "supervision had no effect");
    }

    #[test]
    fn learned_r_is_real_signal() {
        // The per-bin updates must keep conjugate symmetry so r stays real
        // — verified by round-tripping through the spectrum.
        let d = 20;
        let x = make_data(15, d, 13);
        let mut rng = Pcg64::new(14);
        let r0 = rng.normal_vec(d);
        let planner = Planner::new();
        let mut opt = TimeFreqOptimizer::new(d, TimeFreqConfig::new(d), planner.clone());
        let r = opt.run(&x, &r0, None);
        let spec = real::rfft_full(&planner, &r);
        assert!(real::symmetry_error(&spec) < 1e-6);
    }

    #[test]
    fn cached_objective_equals_reference() {
        // The satellite contract: objective() reading the spectrum cache
        // computes the exact same arithmetic, in the same order, as the
        // old per-row-re-FFT path — equality, not approximation.
        for (n, d) in [(25usize, 16usize), (40, 21), (130, 32)] {
            let x = make_data(n, d, 100 + d as u64);
            let mut rng = Pcg64::new(101);
            let r = rng.normal_vec(d);
            let planner = Planner::new();
            let cfg = TimeFreqConfig::new(d.min(12));
            let opt = TimeFreqOptimizer::new(d, cfg.clone(), planner.clone());
            let cache = SpectrumCache::build(&x, &planner, 4);
            let cached = opt.objective(&cache, &r);
            let legacy = reference::objective(&planner, d, &cfg, &x, &r);
            assert!(
                (cached - legacy).abs() <= 1e-9 * legacy.abs().max(1.0),
                "n={n} d={d}: cached {cached} vs legacy {legacy}"
            );
        }
    }

    #[test]
    fn single_block_run_is_bit_identical_to_reference() {
        // With n ≤ DETERMINISTIC_BLOCK the blocked reduction degenerates
        // to the legacy running sum, so the whole refactor must be
        // bit-preserving there: same r, same trace, to the last ulp.
        for d in [16usize, 21] {
            let n = 40;
            assert!(n <= DETERMINISTIC_BLOCK);
            let x = make_data(n, d, 200 + d as u64);
            let mut rng = Pcg64::new(201);
            let r0 = rng.normal_vec(d);
            let planner = Planner::new();
            let mut cfg = TimeFreqConfig::new(d);
            cfg.iters = 4;
            let (r_legacy, trace_legacy) =
                reference::run(&planner, d, &cfg, &x, &r0, None);
            let mut opt = TimeFreqOptimizer::new(d, cfg, planner);
            let r_new = opt.run(&x, &r0, None);
            for (a, b) in r_new.iter().zip(&r_legacy) {
                assert_eq!(a.to_bits(), b.to_bits(), "d={d}");
            }
            for (a, b) in opt.objective_trace.iter().zip(&trace_legacy) {
                assert_eq!(a.to_bits(), b.to_bits(), "d={d}");
            }
        }
    }

    #[test]
    fn parallel_matches_serial_bit_for_bit() {
        // The deterministic-flag contract, in-module smoke version (the
        // full property sweep lives in rust/tests/train_parallel.rs):
        // thread count must not change a single output bit.
        let d = 24;
        let n = 150; // several DETERMINISTIC_BLOCK blocks
        let x = make_data(n, d, 300);
        let mut rng = Pcg64::new(301);
        let r0 = rng.normal_vec(d);
        let planner = Planner::new();
        let mut cfg = TimeFreqConfig::new(d);
        cfg.iters = 4;
        cfg.deterministic = true;
        cfg.threads = 1;
        let mut serial = TimeFreqOptimizer::new(d, cfg.clone(), planner.clone());
        let r_serial = serial.run(&x, &r0, None);
        cfg.threads = 4;
        let mut par = TimeFreqOptimizer::new(d, cfg, planner);
        let r_par = par.run(&x, &r0, None);
        for (a, b) in r_par.iter().zip(&r_serial) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
        // 150 rows / 64-row blocks = 3 blocks, so 4 requested workers
        // clamp to the 3 the pass can actually use.
        assert_eq!(par.report.threads, 3);
        assert_eq!(serial.report.threads, 1);
    }

    #[test]
    fn report_records_the_run() {
        let d = 16;
        let x = make_data(30, d, 400);
        let mut rng = Pcg64::new(401);
        let r0 = rng.normal_vec(d);
        let mut cfg = TimeFreqConfig::new(d);
        cfg.iters = 3;
        let mut opt = TimeFreqOptimizer::new(d, cfg, Planner::new());
        let _ = opt.run(&x, &r0, None);
        let rep = &opt.report;
        assert_eq!(rep.n, 30);
        assert_eq!(rep.d, d);
        assert_eq!(rep.iters, 3);
        assert_eq!(rep.objective_trace.len(), 3);
        assert_eq!(rep.iter_ms.len(), 3);
        assert_eq!(rep.spectrum_cache_bytes, 30 * d * 16);
        assert!(rep.total_ms >= 0.0);
    }
}
