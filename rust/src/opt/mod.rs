//! Learning machinery for data-dependent CBE.

pub mod cubic;
pub mod timefreq;

pub use timefreq::{PairSet, TimeFreqConfig, TimeFreqOptimizer};
