//! Learning machinery for data-dependent CBE.
//!
//! [`timefreq`] is the §4 time–frequency alternating optimizer, rebuilt
//! on the conjugate-symmetric **half-spectrum** substrate
//! ([`crate::fft::RealFft`]): every row spectrum F(xᵢ) is computed
//! exactly once into a shared [`SpectrumCache`] holding only the
//! ⌊d/2⌋+1 independent bins (~8·n·d bytes), and every pass — M, the
//! per-iteration time-domain sweep, the §6 pair penalty, the per-bin
//! frequency solve, the full objective — operates on half-spectra; the
//! per-row work fans out across core-capped scoped threads with blocked
//! (optionally thread-count-invariant) reductions, and
//! [`TimeFreqConfig::cache_budget`] bounds resident memory by streaming
//! block-aligned tiles when the cache would exceed it (bit-identical
//! results either way). [`cubic`] supplies the closed-form quartic
//! minimizer behind the per-bin frequency updates.
//!
//! Training entry points: [`crate::encoders::CbeTrainer`] (the high
//! level API, produces a [`crate::encoders::CbeOpt`] + [`TrainReport`]),
//! or [`TimeFreqOptimizer`] directly when the caller manages its own
//! cache. `timefreq::reference` preserves the old per-row-re-FFT serial
//! loop and the PR-4 full-spectrum cached loop as bench baselines and
//! equality oracles.

pub mod cubic;
pub mod timefreq;

pub use timefreq::{
    PairSet, SpectrumCache, TimeFreqConfig, TimeFreqOptimizer, TrainReport,
};
