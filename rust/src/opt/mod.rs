//! Learning machinery for data-dependent CBE.
//!
//! [`timefreq`] is the §4 time–frequency alternating optimizer, rebuilt
//! on the thread-safe FFT substrate: every row spectrum F(xᵢ) is
//! computed exactly once into a shared [`SpectrumCache`] and every pass
//! — M, the per-iteration time-domain sweep, the §6 pair penalty, the
//! full objective — reads the cache; the per-row work fans out across
//! core-capped scoped threads with blocked (optionally
//! thread-count-invariant) reductions. [`cubic`] supplies the
//! closed-form quartic minimizer behind the per-bin frequency updates.
//!
//! Training entry points: [`crate::encoders::CbeTrainer`] (the high
//! level API, produces a [`crate::encoders::CbeOpt`] + [`TrainReport`]),
//! or [`TimeFreqOptimizer`] directly when the caller manages its own
//! cache. `timefreq::reference` preserves the old per-row-re-FFT serial
//! loop as the bench baseline and equality oracle.

pub mod cubic;
pub mod timefreq;

pub use timefreq::{
    PairSet, SpectrumCache, TimeFreqConfig, TimeFreqOptimizer, TrainReport,
};
