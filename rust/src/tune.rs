//! Host calibration for the parallel fan-outs.
//!
//! Both scoped-thread fan-outs in this crate — the batch-encode engine
//! ([`crate::projections::CirculantProjection::encode_batch_into`]) and
//! the CBE-opt trainer ([`crate::opt::TimeFreqOptimizer`]) — degrade to a
//! serial sweep when the total work (rows × d) is too small to amortize
//! thread spawn/join. The cutover used to be a fixed `1 << 14`; the right
//! value depends on the host (spawn cost, core count, FFT throughput), so
//! [`min_parallel_work`] calibrates it once per process with a micro-probe
//! and every fan-out reads the same calibrated threshold.
//!
//! The probe measures two quantities:
//!
//! * **spawn overhead** — the wall time of a `std::thread::scope` that
//!   spawns one no-op thread per core (median of a few trials), and
//! * **per-element FFT cost** — the amortized per-element time of a warm
//!   radix-2 transform (the dominant kernel under both fan-outs).
//!
//! Fanning out pays once the serial time exceeds a few multiples of the
//! spawn overhead, so the threshold is `work` such that
//! `work × t_elem ≈ OVERHEAD_FACTOR × t_spawn`, clamped between
//! [`MIN_WORK_FLOOR`] and [`MIN_WORK_CEIL`].
//!
//! Calibration never changes *results*: both fan-outs are bit-exact
//! against their serial paths at any thread count, so a per-host
//! threshold only moves the speed cliff, never the output.
//!
//! The probe measures the **active kernel**: its transform runs through
//! [`Plan::transform_with`] and therefore the same [`crate::simd`]
//! dispatch as the hot loops, so a host where the AVX2 butterflies engage
//! calibrates against AVX2 timings (and a `CBE_SIMD=0` run calibrates
//! against scalar ones) — the threshold always reflects the kernel the
//! fan-outs will actually execute.
//!
//! Env knobs:
//! * `CBE_MIN_PARALLEL_WORK=N` — skip probing, use N (clamp still
//!   applies; useful for benches and deterministic CI timing). An
//!   unparsable value (`"16k"`, `"auto"`, …) warns on stderr and uses
//!   the fixed [`DEFAULT_MIN_WORK`] — never the nondeterministic probe
//!   the operator was clearly trying to pin down;
//! * `CBE_CALIBRATE=0` — disable probing, use the fixed default
//!   (honored even when `CBE_MIN_PARALLEL_WORK` fails to parse).
//!
//! The probe also falls back to the default when its measurements are
//! degenerate (zero-resolution timer, absurd spawn cost) — noisy hosts
//! get the known-good fixed threshold rather than a garbage one.

use crate::fft::{C64, Dir, FftScratch, Plan};
use std::sync::OnceLock;
use std::time::{Duration, Instant};

/// The pre-calibration default (and the fallback when probing is
/// disabled or noisy): the fixed cutover the encode engine shipped with.
pub const DEFAULT_MIN_WORK: usize = 1 << 14;
/// Calibration clamp: never fan out below this work even on a host that
/// probes as spawn-cheap (scheduler noise dominates down there).
pub const MIN_WORK_FLOOR: usize = 1 << 12;
/// Calibration clamp: always fan out above this work even on a host that
/// probes as spawn-expensive (the probe can only overestimate so much).
pub const MIN_WORK_CEIL: usize = 1 << 18;

/// Serial time ≈ this many spawn overheads before the fan-out engages.
const OVERHEAD_FACTOR: f64 = 4.0;
/// Probe transform length (radix-2, warm plan — the hot-loop kernel).
const PROBE_N: usize = 256;
/// Transforms per timing trial.
const PROBE_REPS: usize = 64;

static MIN_WORK: OnceLock<usize> = OnceLock::new();

/// The calibrated minimum total work (rows × d) for a scoped-thread
/// fan-out. Probes once per process on first call; every later call is a
/// single atomic load.
pub fn min_parallel_work() -> usize {
    *MIN_WORK.get_or_init(calibrate)
}

fn calibrate() -> usize {
    let min_work = std::env::var("CBE_MIN_PARALLEL_WORK").ok();
    let probing_disabled = std::env::var("CBE_CALIBRATE").is_ok_and(|v| v == "0");
    if let Some(work) = resolve_override(min_work.as_deref(), probing_disabled) {
        return work;
    }
    let cores = std::thread::available_parallelism()
        .map(|p| p.get())
        .unwrap_or(1);
    if cores <= 1 {
        // No fan-out will ever engage; the threshold is moot.
        return DEFAULT_MIN_WORK;
    }

    let t_spawn = probe_spawn(cores);
    let t_elem = probe_fft_per_elem();
    // Noise guards: a zero measurement means the timer resolution beat
    // the probe; a spawn cost above 50 ms means the host is swamped.
    if t_spawn == Duration::ZERO
        || t_elem <= 0.0
        || t_spawn > Duration::from_millis(50)
    {
        return DEFAULT_MIN_WORK;
    }

    let work = OVERHEAD_FACTOR * t_spawn.as_secs_f64() / t_elem;
    (work as usize).clamp(MIN_WORK_FLOOR, MIN_WORK_CEIL)
}

/// Pure resolution of the env overrides (extracted so it can be unit
/// tested without racing the process environment or the `OnceLock`).
/// `Some(threshold)` short-circuits the probe; `None` means probe.
///
/// A set-but-unparsable `CBE_MIN_PARALLEL_WORK` used to fall through to
/// the nondeterministic probe — exactly what an operator pinning the
/// threshold was trying to avoid. Now it warns on stderr and resolves to
/// the fixed [`DEFAULT_MIN_WORK`] (which also honors `CBE_CALIBRATE=0`,
/// trivially, since the probe is never reached).
fn resolve_override(min_work: Option<&str>, probing_disabled: bool) -> Option<usize> {
    if let Some(v) = min_work {
        match v.trim().parse::<usize>() {
            Ok(n) => return Some(n.clamp(MIN_WORK_FLOOR, MIN_WORK_CEIL)),
            Err(_) => {
                eprintln!(
                    "cbe: CBE_MIN_PARALLEL_WORK='{v}' is not an integer; \
                     using the fixed default {DEFAULT_MIN_WORK} (probe skipped)"
                );
                return Some(DEFAULT_MIN_WORK);
            }
        }
    }
    if probing_disabled {
        return Some(DEFAULT_MIN_WORK);
    }
    None
}

/// Median wall time of a scope spawning one no-op thread per core.
fn probe_spawn(cores: usize) -> Duration {
    let mut trials: Vec<Duration> = (0..5)
        .map(|_| {
            let t0 = Instant::now();
            std::thread::scope(|scope| {
                for _ in 0..cores {
                    scope.spawn(|| std::hint::black_box(0u64));
                }
            });
            t0.elapsed()
        })
        .collect();
    trials.sort();
    trials[trials.len() / 2]
}

/// Amortized per-element seconds of a warm radix-2 transform. The encode
/// and train hot loops both run ~2–3 transforms per row, so scale by 2.5
/// to approximate per-element *row* cost. Runs through the dispatched
/// [`Plan::transform_with`], so it times whichever kernel set (AVX2 or
/// scalar) [`crate::simd::active`] selects for the real workload.
fn probe_fft_per_elem() -> f64 {
    let plan = Plan::new(PROBE_N);
    let mut scratch = FftScratch::new();
    let mut buf: Vec<C64> = (0..PROBE_N)
        .map(|i| C64::new((i % 7) as f64 - 3.0, (i % 5) as f64 - 2.0))
        .collect();
    // Warm-up (twiddle tables are prebuilt; this warms caches).
    plan.transform_with(&mut buf, Dir::Forward, &mut scratch);
    let t0 = Instant::now();
    for _ in 0..PROBE_REPS {
        plan.transform_with(&mut buf, Dir::Forward, &mut scratch);
        std::hint::black_box(&buf);
    }
    let dt = t0.elapsed().as_secs_f64();
    2.5 * dt / (PROBE_REPS * PROBE_N) as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn threshold_is_clamped_and_stable() {
        let a = min_parallel_work();
        let b = min_parallel_work();
        assert_eq!(a, b, "calibration must be one-shot");
        assert!((MIN_WORK_FLOOR..=MIN_WORK_CEIL).contains(&a), "work={a}");
    }

    #[test]
    fn parsable_override_is_clamped() {
        assert_eq!(resolve_override(Some("32768"), false), Some(32768));
        assert_eq!(resolve_override(Some(" 32768 "), false), Some(32768));
        assert_eq!(resolve_override(Some("1"), false), Some(MIN_WORK_FLOOR));
        assert_eq!(
            resolve_override(Some("99999999999"), false),
            Some(MIN_WORK_CEIL)
        );
    }

    #[test]
    fn unparsable_override_falls_back_to_default_not_probe() {
        // The PR-5 bugfix: "16k" used to fall through to the
        // nondeterministic probe; now it pins the fixed default …
        assert_eq!(resolve_override(Some("16k"), false), Some(DEFAULT_MIN_WORK));
        assert_eq!(resolve_override(Some(""), false), Some(DEFAULT_MIN_WORK));
        // … and CBE_CALIBRATE=0 stays honored alongside the bad value.
        assert_eq!(resolve_override(Some("16k"), true), Some(DEFAULT_MIN_WORK));
    }

    #[test]
    fn calibrate_0_disables_probing() {
        assert_eq!(resolve_override(None, true), Some(DEFAULT_MIN_WORK));
        assert_eq!(resolve_override(None, false), None);
    }
}
