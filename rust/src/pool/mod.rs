//! Minimal thread pool (no tokio in the offline vendor set).
//!
//! Fixed worker threads pulling closures off an mpsc channel guarded by a
//! mutex — enough for the coordinator's background batching loop and for
//! fanning groundtruth computation across cores when available.

use std::sync::mpsc;
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;

type Job = Box<dyn FnOnce() + Send + 'static>;

/// A fixed-size thread pool. Dropping it joins all workers.
pub struct ThreadPool {
    tx: Option<mpsc::Sender<Job>>,
    workers: Vec<JoinHandle<()>>,
}

impl ThreadPool {
    pub fn new(threads: usize) -> ThreadPool {
        let threads = threads.max(1);
        let (tx, rx) = mpsc::channel::<Job>();
        let rx = Arc::new(Mutex::new(rx));
        let workers = (0..threads)
            .map(|_| {
                let rx = Arc::clone(&rx);
                std::thread::spawn(move || loop {
                    let job = {
                        let guard = rx.lock().unwrap();
                        guard.recv()
                    };
                    match job {
                        Ok(job) => job(),
                        Err(_) => break, // channel closed
                    }
                })
            })
            .collect();
        ThreadPool {
            tx: Some(tx),
            workers,
        }
    }

    /// Submit a job.
    pub fn submit(&self, job: impl FnOnce() + Send + 'static) {
        self.tx
            .as_ref()
            .expect("pool shut down")
            .send(Box::new(job))
            .expect("workers gone");
    }

    /// Run a batch of jobs and wait for all of them.
    pub fn scatter_wait(&self, jobs: Vec<Job>) {
        let (done_tx, done_rx) = mpsc::channel();
        let n = jobs.len();
        for job in jobs {
            let done = done_tx.clone();
            self.submit(move || {
                job();
                let _ = done.send(());
            });
        }
        for _ in 0..n {
            done_rx.recv().expect("worker died");
        }
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        drop(self.tx.take());
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn runs_all_jobs() {
        let pool = ThreadPool::new(2);
        let counter = Arc::new(AtomicUsize::new(0));
        let jobs: Vec<Job> = (0..50)
            .map(|_| {
                let c = Arc::clone(&counter);
                Box::new(move || {
                    c.fetch_add(1, Ordering::SeqCst);
                }) as Job
            })
            .collect();
        pool.scatter_wait(jobs);
        assert_eq!(counter.load(Ordering::SeqCst), 50);
    }

    #[test]
    fn drop_joins_cleanly() {
        let pool = ThreadPool::new(3);
        pool.submit(|| {});
        drop(pool); // must not hang
    }
}
