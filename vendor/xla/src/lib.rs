//! Offline stub of the `xla` PJRT bindings.
//!
//! The build image carries no PJRT shared library, so this path dependency
//! provides the exact compile-time surface `cbe::runtime::Engine` uses.
//! Client construction, HLO-text loading and literal plumbing work; the
//! `compile`/`execute` entry points return a descriptive error at runtime.
//! Every test/bench that needs real PJRT execution gates on the presence of
//! `artifacts/manifest.json` and skips otherwise, so the stub keeps the
//! whole tree building and testable offline. Swapping in the real bindings
//! is a one-line change in the root Cargo.toml.

use std::marker::PhantomData;
use std::path::Path;
use std::rc::Rc;

/// Error type; the engine formats these with `{:?}`.
#[derive(Debug, Clone)]
pub struct XlaError(pub String);

pub type Result<T> = std::result::Result<T, XlaError>;

const UNAVAILABLE: &str =
    "PJRT runtime unavailable: built against the offline xla stub (vendor/xla)";

/// PJRT client handle. Mirrors the real binding's `!Send` (Rc-backed
/// internals) so threading assumptions in the coordinator stay honest.
pub struct PjRtClient {
    _not_send: PhantomData<Rc<()>>,
}

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Ok(PjRtClient {
            _not_send: PhantomData,
        })
    }

    pub fn compile(&self, _computation: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(XlaError(UNAVAILABLE.to_string()))
    }
}

/// Parsed HLO module text (held verbatim; the stub performs no validation
/// beyond reading the file).
pub struct HloModuleProto {
    _text: String,
}

impl HloModuleProto {
    pub fn from_text_file<P: AsRef<Path>>(path: P) -> Result<HloModuleProto> {
        let text = std::fs::read_to_string(path.as_ref())
            .map_err(|e| XlaError(format!("read {}: {e}", path.as_ref().display())))?;
        Ok(HloModuleProto { _text: text })
    }
}

/// An XLA computation built from a parsed HLO module.
pub struct XlaComputation {
    _private: (),
}

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation { _private: () }
    }
}

/// Compiled executable handle. Never actually constructed by the stub
/// (compile errors first), but the full call surface typechecks.
pub struct PjRtLoadedExecutable {
    _not_send: PhantomData<Rc<()>>,
}

impl PjRtLoadedExecutable {
    pub fn execute<L>(&self, _args: &[L]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(XlaError(UNAVAILABLE.to_string()))
    }
}

/// Device buffer returned by execution.
pub struct PjRtBuffer {
    _not_send: PhantomData<Rc<()>>,
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(XlaError(UNAVAILABLE.to_string()))
    }
}

/// Host literal: flat f32 storage plus dims; enough for the engine's
/// vec1/reshape staging and (hypothetical) tuple decomposition.
#[derive(Clone, Debug)]
pub struct Literal {
    data: Vec<f32>,
    dims: Vec<i64>,
}

impl Literal {
    pub fn vec1<D: AsRef<[f32]>>(data: D) -> Literal {
        let data = data.as_ref();
        Literal {
            data: data.to_vec(),
            dims: vec![data.len() as i64],
        }
    }

    pub fn reshape(&self, dims: &[i64]) -> Result<Literal> {
        let count: i64 = dims.iter().product();
        if count != self.data.len() as i64 {
            return Err(XlaError(format!(
                "reshape {:?} -> {dims:?}: element count mismatch",
                self.dims
            )));
        }
        Ok(Literal {
            data: self.data.clone(),
            dims: dims.to_vec(),
        })
    }

    pub fn decompose_tuple(&mut self) -> Result<Vec<Literal>> {
        Err(XlaError(UNAVAILABLE.to_string()))
    }

    pub fn to_vec<T: From<f32>>(&self) -> Result<Vec<T>> {
        Ok(self.data.iter().map(|v| T::from(*v)).collect())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn client_constructs_but_compile_is_gated() {
        let client = PjRtClient::cpu().unwrap();
        let comp = XlaComputation {
            _private: (),
        };
        assert!(client.compile(&comp).is_err());
    }

    #[test]
    fn literal_reshape_checks_counts() {
        let lit = Literal::vec1(&[1.0, 2.0, 3.0, 4.0]);
        assert!(lit.reshape(&[2, 2]).is_ok());
        assert!(lit.reshape(&[3, 2]).is_err());
        let back: Vec<f32> = lit.to_vec().unwrap();
        assert_eq!(back, vec![1.0, 2.0, 3.0, 4.0]);
    }
}
