//! Minimal offline stand-in for the `anyhow` crate.
//!
//! The build image has no crates.io access, so this path dependency provides
//! exactly the surface the repo uses: [`Error`], [`Result`], the [`anyhow!`]
//! and [`bail!`] macros, and the [`Context`] extension trait. Like the real
//! crate, `Error` deliberately does **not** implement `std::error::Error`,
//! which is what makes the blanket `From<E: std::error::Error>` impl (and
//! therefore `?` on arbitrary std errors) coherent.

use std::fmt;

/// A string-backed error value. Context frames are joined front-to-back, so
/// `Display` reads outermost-context first, like anyhow's `{:#}` format.
pub struct Error {
    msg: String,
}

impl Error {
    /// Construct from anything displayable (what `anyhow!` expands to).
    pub fn msg<M: fmt::Display>(m: M) -> Error {
        Error { msg: m.to_string() }
    }

    /// Prepend a context frame.
    pub fn context<C: fmt::Display>(self, c: C) -> Error {
        Error {
            msg: format!("{c}: {}", self.msg),
        }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.msg)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.msg)
    }
}

impl<E: std::error::Error> From<E> for Error {
    fn from(e: E) -> Error {
        Error::msg(e)
    }
}

/// `anyhow::Result<T>`: a `Result` defaulting to [`Error`].
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Attach context to the error branch of a `Result`.
pub trait Context<T> {
    fn context<C: fmt::Display>(self, c: C) -> Result<T>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: fmt::Display> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, c: C) -> Result<T> {
        self.map_err(|e| Error::msg(format!("{c}: {e}")))
    }
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| Error::msg(format!("{}: {e}", f())))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, c: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(c))
    }
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string.
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => {
        $crate::Error::msg(format!($($arg)*))
    };
}

/// Return early with an [`Error`] built from a format string.
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn macro_and_display() {
        let e = anyhow!("bad {} of {}", 1, 2);
        assert_eq!(e.to_string(), "bad 1 of 2");
        assert_eq!(format!("{e:?}"), "bad 1 of 2");
    }

    #[test]
    fn question_mark_on_std_errors() {
        fn inner() -> Result<String> {
            let s = std::str::from_utf8(&[0xff])?;
            Ok(s.to_string())
        }
        assert!(inner().is_err());
    }

    #[test]
    fn context_chains() {
        let r: std::result::Result<(), std::fmt::Error> = Err(std::fmt::Error);
        let e = r.with_context(|| "outer").unwrap_err();
        assert!(e.to_string().starts_with("outer: "));
    }

    #[test]
    fn bail_returns() {
        fn inner(x: u32) -> Result<u32> {
            if x == 0 {
                bail!("zero");
            }
            Ok(x)
        }
        assert_eq!(inner(3).unwrap(), 3);
        assert_eq!(inner(0).unwrap_err().to_string(), "zero");
    }
}
